//! Multi-iteration run simulator — the end-to-end engine behind the
//! paper's headline claim (3.76x mean / 7.54x max over DeepSpeed on real
//! Long-SFT runs, Section 5).
//!
//! The engine is split into two phases with a first-class intermediate:
//!
//! * [`build_run`] drives the scheduling [`ScheduledLoader`] exactly once
//!   and captures everything the scheduler produced — per-iteration global
//!   batches, their [`IterationSchedule`]s, the measured scheduling
//!   wall-clock, and the loader's invocation counter — into a
//!   [`BuiltRun`].  Building is the only phase that performs GDS/DACP
//!   work.
//! * [`price_run`] replays a `BuiltRun` through a cost model on a
//!   topology: pure, deterministic, allocation-lean pricing that produces
//!   the full [`RunReport`] (wall-clock, per-GPU busy, padding, exposed
//!   scheduling, per-rank peak memory + OOM events).
//!   [`price_run_traced`] additionally emits the calibration-trace lane
//!   from the same pass.
//!
//! Build once, price many: the calibrated e2e sweep prices each built
//! schedule under both the calibrated and the analytic model to compute
//! `estimator_error` without a second scheduling pass, and the chrome
//! trace (`cluster::trace::run_trace_built`) renders from the same
//! `BuiltRun`.  [`simulate_run`] / [`simulate_run_traced`] are the
//! one-shot compositions.
//!
//! Two loader modes:
//!
//! * **Synchronous** — schedule, then execute: every scheduling call is on
//!   the critical path, so overhead is additive.
//! * **Pipelined** — the double-buffered DataLoader of Section 4.3:
//!   scheduling of batch *i+1* actually overlaps (scoped background
//!   thread) the execution of batch *i*, so the *exposed* overhead per
//!   iteration is `max(0, sched − exec)` — the near-zero-overhead claim
//!   becomes a measured quantity instead of an assertion.
//!
//! Timing semantics: execution time is *simulated* (cost-model seconds on
//! the modeled cluster); scheduling time is *measured* (wall-clock of the
//! real scheduler in the loader) — exactly the comparison the paper makes,
//! since the DataLoader schedules on host CPUs while GPUs execute.

use crate::calib::TraceRecord;
use crate::cluster::topology::Topology;
use crate::config::ExperimentConfig;
use crate::data::loader::ScheduledLoader;
use crate::data::{Dataset, Sequence};
use crate::memplan::{self, CapacitySource, IterationMemory, MemPlan, OomEvent};
use crate::perfmodel::CostModel;
use crate::rng::Rng;
use crate::scheduler::plan::{IterationSchedule, MicroBatch, SchedError};
use crate::stream::{IngestReport, SpillError, StreamSource};

use super::sim::{simulate_iteration, simulate_iteration_on, IterationSim};

/// How the run engine drives the scheduling DataLoader.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoaderMode {
    /// Scheduling on the critical path (overhead additive).
    Synchronous,
    /// Double-buffered prefetch: schedule batch i+1 while batch i executes.
    Pipelined,
}

impl LoaderMode {
    pub fn name(&self) -> &'static str {
        match self {
            LoaderMode::Synchronous => "synchronous",
            LoaderMode::Pipelined => "pipelined",
        }
    }
}

/// Where the run's global batches come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchSource {
    /// `RunConfig::iterations` i.i.d. batches sampled with replacement
    /// (the paper's iteration-time measurements).
    Sampled,
    /// One full shuffled epoch via `Dataset::epoch_order` — every
    /// sequence exactly once, chunked lazily into batches; the iteration
    /// count is the epoch length and `RunConfig::iterations` is ignored.
    Epoch,
}

/// Parameters of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct RunConfig {
    pub iterations: usize,
    pub mode: LoaderMode,
    pub source: BatchSource,
    /// Disable the scheduler's *internal* thread fan-out (GDS per-rank /
    /// refinement threads) for this run.  Set by callers that already
    /// parallelize at a coarser grain — the e2e sweep's per-cell workers —
    /// so nested fan-outs don't oversubscribe the cores and contaminate
    /// the measured `sched_seconds`.  Schedules are byte-identical either
    /// way (gds oracle tests).
    pub serial_scheduler: bool,
}

impl RunConfig {
    pub fn new(iterations: usize, pipelined: bool) -> Self {
        RunConfig {
            iterations,
            mode: if pipelined { LoaderMode::Pipelined } else { LoaderMode::Synchronous },
            source: BatchSource::Sampled,
            serial_scheduler: false,
        }
    }

    /// A full-epoch run (ROADMAP: epoch-mode runs in the run engine).
    pub fn epoch(pipelined: bool) -> Self {
        let mut run = Self::new(0, pipelined);
        run.source = BatchSource::Epoch;
        run
    }
}

/// One iteration as the scheduler produced it: the sampled global batch,
/// its schedule, the measured scheduling wall-clock, and every piece of
/// per-iteration accounting that does *not* depend on the cost model —
/// computed once at build time so repricing is pure cost arithmetic.
#[derive(Clone, Debug)]
pub struct BuiltIteration {
    pub batch: Vec<Sequence>,
    pub schedule: IterationSchedule,
    /// measured wall-clock of this iteration's scheduling call
    pub sched_seconds: f64,
    /// real data tokens in the global batch
    pub data_tokens: u64,
    /// padding tokens under static per-rank C-token buckets
    pub padded_tokens: u64,
    /// total bucket tokens executed (data + padding)
    pub bucket_tokens: u64,
    pub micro_batches: usize,
    /// memplan peak-memory simulation of this iteration (per-GPU peaks +
    /// OOM events) — a function of the schedule and the memory plan only
    pub memory: IterationMemory,
}

/// Everything one pass of the scheduling DataLoader produced, ready to be
/// priced under any cost model/topology pair (see the module docs).
#[derive(Clone, Debug)]
pub struct BuiltRun {
    pub dp: usize,
    pub cp: usize,
    /// resolved token capacity C the schedules were built against
    pub bucket_size: u32,
    pub mode: LoaderMode,
    /// where `bucket_size` came from (hand-set vs memplan-derived)
    pub capacity_source: CapacitySource,
    /// the physical layout the run's config mapped onto — the canonical
    /// topology [`simulate_run`] prices under
    pub topology: Topology,
    /// the experiment's resolved memory plan (calibrated activation curve
    /// when the config carried a profile with a memory fit)
    pub mem: MemPlan,
    pub iterations: Vec<BuiltIteration>,
    /// GDS/DACP passes the loader performed building this run — pricing
    /// performs none, so this is the run's *total* scheduling work
    pub sched_invocations: usize,
    /// drift events the streaming ingest recorded for this run's corpus —
    /// 0 for in-memory builds.  Accounting only: drift never changes the
    /// schedules (the byte-identity invariant)
    pub drift_events: u64,
    /// page-cache high-water of the stream source that fed this run
    /// (bytes; deterministic frame accounting, not OS RSS) — 0 for
    /// in-memory builds, ≤ the configured budget for streamed ones
    pub peak_stream_rss_bytes: u64,
}

impl BuiltRun {
    /// The built schedules, in iteration order.
    pub fn schedules(&self) -> impl ExactSizeIterator<Item = &IterationSchedule> + '_ {
        self.iterations.iter().map(|it| &it.schedule)
    }

    /// Overwrite every iteration's *measured* scheduling wall-clock with a
    /// fixed value.  Measured time is the one nondeterministic input to
    /// pricing; pinning it makes a priced report (and everything rendered
    /// from it) byte-identical across repeat runs and thread counts — the
    /// e2e sweep's determinism mode and test harnesses use this.
    pub fn pin_sched_seconds(&mut self, per_iteration: f64) {
        for it in &mut self.iterations {
            it.sched_seconds = per_iteration;
        }
    }
}

/// Accounting for one played iteration.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// simulated execution time (Eq. 8 + grad sync)
    pub exec_seconds: f64,
    /// the grad-sync share of `exec_seconds`
    pub grad_sync_seconds: f64,
    /// measured scheduler wall-clock for this batch
    pub sched_seconds: f64,
    /// scheduling time left on the critical path after overlap
    pub exposed_sched_seconds: f64,
    pub utilization: f64,
    pub dp_imbalance: f64,
    pub micro_batches: usize,
    /// real data tokens in the global batch
    pub data_tokens: u64,
    /// padding tokens executed (static per-rank buckets of BucketSize C)
    pub padded_tokens: u64,
    /// total bucket tokens executed (data + padding)
    pub bucket_tokens: u64,
    /// modeled peak memory per GPU (bytes), indexed `dp_rank * cp + cp_rank`
    pub rank_peak_bytes: Vec<f64>,
    /// max of `rank_peak_bytes` / the HBM budget
    pub peak_mem_fraction: f64,
    /// (micro-batch, GPU) pairs whose modeled peak exceeded HBM
    pub oom_events: usize,
}

/// Aggregated result of a simulated multi-iteration run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub dp: usize,
    pub cp: usize,
    pub bucket_size: u32,
    pub mode: LoaderMode,
    pub iterations: Vec<IterationRecord>,
    /// per-GPU accumulated busy compute, indexed `dp_rank * cp + cp_rank`
    pub rank_busy: Vec<f64>,
    /// Σ simulated iteration times
    pub exec_seconds: f64,
    /// Σ measured scheduling wall-clock
    pub sched_seconds: f64,
    /// Σ exposed (un-overlapped) scheduling time
    pub exposed_sched_seconds: f64,
    pub data_tokens: u64,
    pub padded_tokens: u64,
    pub bucket_tokens: u64,
    /// where the bucket size came from (hand-set vs memplan-derived)
    pub capacity_source: CapacitySource,
    /// per-GPU HBM budget the memory simulation ran against (bytes)
    pub hbm_bytes: f64,
    /// run-wide peak memory per GPU (bytes), indexed `dp_rank * cp + cp_rank`
    pub rank_peak_bytes: Vec<f64>,
    /// every modeled OOM across the run, with coordinates
    pub oom_events: Vec<OomEvent>,
    /// GDS/DACP passes performed building this run's schedules — exactly
    /// one per played iteration; repricing the same [`BuiltRun`] adds none
    pub sched_invocations: usize,
    /// drift events the streaming ingest recorded (0 for in-memory runs)
    pub drift_events: u64,
    /// stream page-cache high-water in bytes (0 for in-memory runs)
    pub peak_stream_rss_bytes: u64,
}

impl RunReport {
    pub fn gpus(&self) -> usize {
        self.dp * self.cp
    }

    /// Run-wide peak memory over all GPUs (bytes).
    pub fn peak_mem_bytes(&self) -> f64 {
        self.rank_peak_bytes.iter().copied().fold(0.0, f64::max)
    }

    /// Run-wide peak memory as a fraction of HBM — > 1.0 means at least
    /// one modeled OOM.
    pub fn peak_mem_fraction(&self) -> f64 {
        if self.hbm_bytes > 0.0 {
            self.peak_mem_bytes() / self.hbm_bytes
        } else {
            0.0
        }
    }

    /// Number of modeled OOM events across the run.
    pub fn oom_count(&self) -> usize {
        self.oom_events.len()
    }

    /// End-to-end wall-clock: execution plus whatever scheduling could not
    /// hide behind it.
    pub fn wall_seconds(&self) -> f64 {
        self.exec_seconds + self.exposed_sched_seconds
    }

    /// Mean busy-compute fraction over all GPUs, relative to execution time.
    pub fn utilization(&self) -> f64 {
        let denom = self.gpus() as f64 * self.exec_seconds;
        if denom > 0.0 {
            self.rank_busy.iter().sum::<f64>() / denom
        } else {
            0.0
        }
    }

    /// Utilization against the full wall-clock (exposed scheduling is GPU
    /// idle time — this is what the pipelined loader protects).
    pub fn effective_utilization(&self) -> f64 {
        let denom = self.gpus() as f64 * self.wall_seconds();
        if denom > 0.0 {
            self.rank_busy.iter().sum::<f64>() / denom
        } else {
            0.0
        }
    }

    /// Fraction of the wall-clock spent on exposed scheduling — the
    /// paper's "near-zero overhead" number.
    pub fn sched_overhead_fraction(&self) -> f64 {
        let wall = self.wall_seconds();
        if wall > 0.0 {
            self.exposed_sched_seconds / wall
        } else {
            0.0
        }
    }

    /// Fraction of executed bucket tokens that were padding.
    pub fn padding_fraction(&self) -> f64 {
        if self.bucket_tokens == 0 {
            0.0
        } else {
            self.padded_tokens as f64 / self.bucket_tokens as f64
        }
    }

    pub fn mean_dp_imbalance(&self) -> f64 {
        if self.iterations.is_empty() {
            return 1.0;
        }
        self.iterations.iter().map(|r| r.dp_imbalance).sum::<f64>()
            / self.iterations.len() as f64
    }

    /// Per-GPU idle seconds over the run (relative to execution time).
    pub fn rank_idle(&self) -> Vec<f64> {
        self.rank_busy
            .iter()
            .map(|&b| (self.exec_seconds - b).max(0.0))
            .collect()
    }

    pub fn total_micro_batches(&self) -> usize {
        self.iterations.iter().map(|r| r.micro_batches).sum()
    }

    /// Simulated end-to-end speedup of this run over a baseline run of the
    /// same workload.
    pub fn speedup_over(&self, baseline: &RunReport) -> f64 {
        let own = self.wall_seconds();
        if own > 0.0 {
            baseline.wall_seconds() / own
        } else {
            f64::INFINITY
        }
    }
}

/// Padding accounting for one micro-batch under static per-rank buckets:
/// every CP rank executes a C-token buffer; whatever its local sequences
/// plus its 1/N shard of the distributed sequences don't fill is padding.
/// The fill rule itself lives in [`MicroBatch::rank_used_tokens_iter`],
/// shared with memplan's peak-memory simulation.
fn micro_batch_padding(mb: &MicroBatch, bucket_size: u32, cp: usize) -> (u64, u64) {
    let mut padded = 0u64;
    let mut bucket = 0u64;
    for used in mb.rank_used_tokens_iter(cp) {
        // a baseline policy may overfill C; charge what actually runs
        let cap = (bucket_size as u64).max(used);
        padded += cap - used;
        bucket += cap;
    }
    (padded, bucket)
}

/// Everything the trace emitter needs about the modeled cluster.
#[derive(Clone, Copy)]
struct TraceCtx<'a> {
    cost: &'a CostModel,
    topo: &'a Topology,
    bucket_size: u32,
    cp: usize,
}

/// What a real cluster's profiler would have measured for one iteration,
/// in the calibration trace schema: per-kernel/per-collective aggregate
/// seconds alongside the features they are affine in.  Mirrors the exact
/// pricing the simulator applied (cross-node CP groups at IB, the
/// gradient reduce-scatter at IB when the DP group spans nodes).
fn trace_record_for(
    i: usize,
    batch: &[Sequence],
    sched: &IterationSchedule,
    sim: &IterationSim,
    imem: &IterationMemory,
    ctx: &TraceCtx,
) -> TraceRecord {
    let TraceCtx { cost, topo, bucket_size, cp } = *ctx;
    let cp = cp.max(1);
    // mirrors the run engine's sim selection: an unplaced schedule is
    // priced uniformly intra-node by `simulate_iteration`
    let placed = topo.dp == sched.ranks.len();
    let mut r = TraceRecord::empty(i, sched.ranks.len(), cp);
    r.seq_lens = batch.iter().map(|s| s.len).collect();
    for (d, rank) in sched.ranks.iter().enumerate() {
        let cross_cp = placed && topo.cp > 1 && d < topo.dp && topo.cp_group_crosses_nodes(d);
        for mb in &rank.micro_batches {
            let lens = mb.lens();
            if lens.is_empty() {
                continue;
            }
            r.dispatches += 1.0;
            r.overhead_seconds += cost.hw.step_overhead_s;
            // local packed kernels: one per (CP rank, layer)
            for j in 0..cp {
                let w: f64 = mb.plan.locals_of(j).map(|k| cost.seq_layer_flops(lens[k])).sum();
                if w > 0.0 {
                    r.comp_flops += cost.layers as f64 * w;
                    r.comp_kernels += cost.layers as f64;
                    r.comp_seconds += cost.t_comp_per_layer(w);
                }
            }
            // distributed shards: every CP rank runs the same 1/N kernel
            let w_dist: f64 = mb
                .plan
                .distributed()
                .map(|k| cost.seq_layer_flops(lens[k]))
                .sum::<f64>()
                / cp as f64;
            if w_dist > 0.0 {
                r.comp_flops += cp as f64 * cost.layers as f64 * w_dist;
                r.comp_kernels += cp as f64 * cost.layers as f64;
                r.comp_seconds += cp as f64 * cost.t_comp_per_layer(w_dist);
            }
            // K/V exchange collectives
            let dist_tokens: u64 = mb.plan.distributed().map(|k| lens[k] as u64).sum();
            if dist_tokens > 0 {
                let (launches, bytes) = cost.kv_launches_and_bytes(dist_tokens);
                let comm = if cross_cp { &cost.inter_comm } else { &cost.comm };
                let seconds = comm.alpha_s_per_byte * bytes + comm.fixed_s * launches;
                if cross_cp {
                    r.xcomm_launches += launches;
                    r.xcomm_bytes += bytes;
                    r.xcomm_seconds += seconds;
                } else {
                    r.comm_launches += launches;
                    r.comm_bytes += bytes;
                    r.comm_seconds += seconds;
                }
            }
        }
    }
    // ZeRO-2 gradient reduce-scatter: one collective per iteration, priced
    // by the DP group's node placement
    let dp = sched.ranks.len();
    if dp > 1 {
        let bytes = cost.grad_sync_bytes(dp);
        let cross_dp = placed && topo.any_dp_group_crosses_nodes();
        let comm = if cross_dp { &cost.inter_comm } else { &cost.comm };
        let seconds = comm.alpha_s_per_byte * bytes + comm.fixed_s;
        if cross_dp {
            r.xcomm_launches += 1.0;
            r.xcomm_bytes += bytes;
            r.xcomm_seconds += seconds;
        } else {
            r.comm_launches += 1.0;
            r.comm_bytes += bytes;
            r.comm_seconds += seconds;
        }
    }
    // memory lane: the worst GPU's executed bucket and its modeled peak
    let mut max_tokens = 0u64;
    for rank in &sched.ranks {
        for mb in &rank.micro_batches {
            for used in mb.rank_used_tokens_iter(cp) {
                max_tokens = max_tokens.max((bucket_size as u64).max(used));
            }
        }
    }
    r.bucket_tokens = max_tokens;
    r.peak_bytes = imem.peak_bytes();
    r.iteration_seconds = sim.total_time;
    r
}

/// Drive the scheduling DataLoader once over `ds` and capture everything
/// it produced.  This is the *only* phase that performs GDS/DACP work;
/// the result can be priced any number of times by [`price_run`].
///
/// `run.mode` is authoritative for the loader mode; `cfg.pipelined` is
/// only the config-surface default callers feed into [`RunConfig::new`]
/// (passing a different mode is how the e2e example contrasts the two
/// modes on one config).
pub fn build_run(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    run: &RunConfig,
) -> Result<BuiltRun, SchedError> {
    // resolve the capacity authority up front: under HbmDerived the bucket
    // size below is the memplan-derived C, and an infeasible HBM budget is
    // an error before any scheduling happens
    let cfg = cfg.resolve_capacity()?;
    // cross-node CP groups pay inter-node bandwidth in the simulator; a
    // layout the topology model cannot place (oversubscribed ranks, bad CP
    // degree) is a configuration error, not a silent NVLink fallback
    let topology = match cfg.cluster.topology() {
        Ok(t) => t,
        Err(e) => return Err(SchedError::BadTopology { reason: e.to_string() }),
    };
    let mem = cfg.mem_plan();
    let (bucket_size, cp) = (cfg.bucket_size, cfg.cluster.cp);
    let batch_size = cfg.cluster.batch_size;
    // lazy epoch: O(dataset) shuffled ids with one scratch batch in
    // flight, never the whole epoch's materialized batch list.  The
    // shuffle and chunking are `Dataset::epoch_batches`' exactly, so
    // the schedules are byte-identical to the old materialized path
    // (pinned by `lazy_epoch_build_matches_materialized_batches`).
    let epoch_order = match run.source {
        BatchSource::Epoch => Some(ds.epoch_order(cfg.seed)),
        BatchSource::Sampled => None,
    };
    let iterations = epoch_order
        .as_ref()
        .map_or(run.iterations, |o| o.len().div_ceil(batch_size.max(1)));
    let mut built: Vec<BuiltIteration> = Vec::with_capacity(iterations);
    let sched_invocations;
    {
        let mut capture = |i: usize, batch: &[Sequence], sched: &IterationSchedule, sched_s: f64| {
            built.push(capture_iteration(i, batch, sched, sched_s, &mem, bucket_size, cp));
        };
        let mut loader = ScheduledLoader::new(ds, &cfg);
        loader.sched_parallel = !run.serial_scheduler;
        sched_invocations = match (run.mode, &epoch_order) {
            (LoaderMode::Synchronous, None) => {
                let mut loader = loader;
                loader.run_synchronous(iterations, &mut capture)?;
                loader.sched_invocations
            }
            (LoaderMode::Synchronous, Some(order)) => {
                let mut loader = loader;
                loader.run_synchronous_order(order, batch_size, &mut capture)?;
                loader.sched_invocations
            }
            (LoaderMode::Pipelined, None) => {
                loader.run_pipelined(iterations, &mut capture)?.sched_invocations
            }
            (LoaderMode::Pipelined, Some(order)) => {
                loader.run_pipelined_order(order, batch_size, &mut capture)?.sched_invocations
            }
        };
    }
    Ok(BuiltRun {
        dp: cfg.cluster.dp,
        cp,
        bucket_size,
        mode: run.mode,
        capacity_source: cfg.memory.source,
        topology,
        mem,
        iterations: built,
        sched_invocations,
        drift_events: 0,
        peak_stream_rss_bytes: 0,
    })
}

/// Capture one scheduled iteration plus every cost-model-independent
/// piece of accounting (padding, token sums, memory simulation) so
/// pricing passes never recompute them.  Shared by [`build_run`] and
/// [`build_run_streamed`]: both builders produce the same
/// [`BuiltIteration`] for the same batch/schedule pair, which is what
/// makes the spilled-vs-in-memory byte-identity testable at the
/// `BuiltRun` level.
fn capture_iteration(
    i: usize,
    batch: &[Sequence],
    sched: &IterationSchedule,
    sched_s: f64,
    mem: &MemPlan,
    bucket_size: u32,
    cp: usize,
) -> BuiltIteration {
    let mut padded = 0u64;
    let mut bucket = 0u64;
    let mut n_mb = 0usize;
    for rank in &sched.ranks {
        for mb in &rank.micro_batches {
            let (p, b) = micro_batch_padding(mb, bucket_size, cp);
            padded += p;
            bucket += b;
            n_mb += 1;
        }
    }
    BuiltIteration {
        batch: batch.to_vec(),
        schedule: sched.clone(),
        sched_seconds: sched_s,
        data_tokens: batch.iter().map(|s| s.len as u64).sum(),
        padded_tokens: padded,
        bucket_tokens: bucket,
        micro_batches: n_mb,
        memory: memplan::iteration_memory(sched, mem, bucket_size, cp, i),
    }
}

/// [`build_run`] against a spilled corpus: batches are resolved through
/// the stream source's bounded-RAM page cache instead of a materialized
/// [`Dataset`], replaying the in-memory path's RNG draws exactly — one
/// `rng.below(n)` per sampled slot, the same seeded Fisher-Yates epoch
/// shuffle — so the resulting schedules are byte-identical to
/// [`build_run`]'s (pinned by `rust/tests/stream.rs` and the CI
/// schedule-digest cmp gate).
///
/// The loader is driven synchronously regardless of `run.mode`: the page
/// cache already decouples batch production from disk, and pipelined and
/// synchronous builds are byte-identical by construction.  `run.mode` is
/// still recorded on the [`BuiltRun`], so pricing's overhead-exposure
/// semantics are unchanged.
///
/// `ingest` carries what the one-pass ingestion learned about the corpus
/// (drift events, length sketch) into the run's accounting — never into
/// its schedules.
pub fn build_run_streamed(
    src: &mut StreamSource,
    ingest: &IngestReport,
    cfg: &ExperimentConfig,
    run: &RunConfig,
) -> Result<BuiltRun, SchedError> {
    let cfg = cfg.resolve_capacity()?;
    let topology = match cfg.cluster.topology() {
        Ok(t) => t,
        Err(e) => return Err(SchedError::BadTopology { reason: e.to_string() }),
    };
    let mem = cfg.mem_plan();
    let (bucket_size, cp) = (cfg.bucket_size, cfg.cluster.cp);
    let batch_size = cfg.cluster.batch_size.max(1);
    let epoch_order = match run.source {
        BatchSource::Epoch => Some(src.epoch_order(cfg.seed)),
        BatchSource::Sampled => None,
    };
    let iterations = epoch_order
        .as_ref()
        .map_or(run.iterations, |o| o.len().div_ceil(batch_size));
    // the loader only schedules here (batches come from the stream), so
    // it wraps an empty placeholder dataset; its sampling RNG is never
    // drawn from — the replayed draw stream below is the authoritative one
    let placeholder = Dataset { name: src.name().to_string(), lengths: Vec::new() };
    let mut loader = ScheduledLoader::new(&placeholder, &cfg);
    loader.sched_parallel = !run.serial_scheduler;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut batch: Vec<Sequence> = Vec::with_capacity(batch_size);
    let mut built: Vec<BuiltIteration> = Vec::with_capacity(iterations);
    let stream_err = |e: SpillError| SchedError::Stream { reason: e.to_string() };
    for i in 0..iterations {
        match &epoch_order {
            Some(order) => {
                let lo = i * batch_size;
                let hi = (lo + batch_size).min(order.len());
                src.fill_batch_from_ids(&order[lo..hi], &mut batch)
                    .map_err(stream_err)?;
            }
            None => src
                .fill_sampled_batch(&mut rng, batch_size, &mut batch)
                .map_err(stream_err)?,
        }
        let sched = loader.schedule_batch(&batch)?;
        built.push(capture_iteration(
            i,
            &batch,
            &sched,
            loader.last_sched_seconds(),
            &mem,
            bucket_size,
            cp,
        ));
    }
    Ok(BuiltRun {
        dp: cfg.cluster.dp,
        cp,
        bucket_size,
        mode: run.mode,
        capacity_source: cfg.memory.source,
        topology,
        mem,
        iterations: built,
        sched_invocations: loader.sched_invocations,
        drift_events: ingest.drift_events.len() as u64,
        peak_stream_rss_bytes: src.peak_resident_bytes(),
    })
}

/// Order-sensitive FNV-1a digest over everything schedule-shaped in a
/// built run: each iteration's global batch (ids + lengths) and every
/// micro-batch's sequence list and DACP assignment.  Streamed and
/// in-memory builds of the same configuration hash identically; the CI
/// byte-identity gate `cmp`s digest files rather than full reports,
/// because the reports legitimately differ in the stream-only accounting
/// fields (`drift_events`, `peak_stream_rss_bytes`).
pub fn schedule_digest(built: &BuiltRun) -> u64 {
    let mut bytes: Vec<u8> = Vec::new();
    for (i, it) in built.iterations.iter().enumerate() {
        bytes.extend_from_slice(&(i as u64).to_le_bytes());
        bytes.extend_from_slice(&(it.batch.len() as u64).to_le_bytes());
        for s in &it.batch {
            bytes.extend_from_slice(&s.id.to_le_bytes());
            bytes.extend_from_slice(&s.len.to_le_bytes());
        }
        for rank in &it.schedule.ranks {
            bytes.extend_from_slice(&(rank.micro_batches.len() as u64).to_le_bytes());
            for mb in &rank.micro_batches {
                bytes.extend_from_slice(&(mb.seqs.len() as u64).to_le_bytes());
                for s in &mb.seqs {
                    bytes.extend_from_slice(&s.id.to_le_bytes());
                    bytes.extend_from_slice(&s.len.to_le_bytes());
                }
                for &a in &mb.plan.assign {
                    bytes.extend_from_slice(&a.to_le_bytes());
                }
            }
        }
    }
    crate::coordinator::state::fnv1a(&bytes)
}

/// Price a [`BuiltRun`] under a cost model on a topology: pure,
/// deterministic (given the built run's captured scheduling wall-clock),
/// and schedule-free — no GDS/DACP work happens here, so repricing under
/// as many models as needed costs only simulation arithmetic.
pub fn price_run(built: &BuiltRun, cost: &CostModel, topo: &Topology) -> RunReport {
    price_run_impl(built, cost, topo, None)
}

/// [`price_run`] with the calibration trace emitter attached: alongside
/// the report, returns one [`TraceRecord`] per iteration in the
/// `calib::trace` schema — the measurements a real cluster's profiler
/// would have produced for this run — from the same pricing pass.
pub fn price_run_traced(
    built: &BuiltRun,
    cost: &CostModel,
    topo: &Topology,
) -> (RunReport, Vec<TraceRecord>) {
    let mut records = Vec::with_capacity(built.iterations.len());
    let report = price_run_impl(built, cost, topo, Some(&mut records));
    (report, records)
}

fn price_run_impl(
    built: &BuiltRun,
    cost: &CostModel,
    topo: &Topology,
    mut trace: Option<&mut Vec<TraceRecord>>,
) -> RunReport {
    // pricing under a *differently-laid-out* topology (node-contained vs
    // node-crossing) is the point of the API; pricing under a different
    // dp×cp shape would silently drop all cross-node pricing via the
    // defensive per-iteration fallback below — refuse loudly instead
    // (PR 3 made unplaceable layouts a hard error for the same reason)
    assert!(
        topo.dp == built.dp && topo.cp == built.cp,
        "price_run: topology is {}x{} but the built run is {}x{} — \
         schedules can only be priced on the dp×cp shape they were built for",
        topo.dp,
        topo.cp,
        built.dp,
        built.cp,
    );
    let dp = built.dp;
    let cp = built.cp;
    let bucket_size = built.bucket_size;
    let mem = &built.mem;
    let mut records: Vec<IterationRecord> = Vec::with_capacity(built.iterations.len());
    let mut rank_busy = vec![0.0f64; dp * cp];
    let mut rank_peak = vec![0.0f64; dp * cp];
    let mut oom_events: Vec<OomEvent> = Vec::new();

    for (i, it) in built.iterations.iter().enumerate() {
        let sched = &it.schedule;
        let sim = if topo.dp == sched.ranks.len() {
            simulate_iteration_on(sched, cost, topo)
        } else {
            simulate_iteration(sched, cost, cp)
        };
        // padding, token sums and the memory simulation are cost-model
        // independent — read them off the built run instead of redoing
        // the work on every pricing
        let imem = &it.memory;
        if let Some(out) = trace.as_deref_mut() {
            let ctx = TraceCtx { cost, topo, bucket_size, cp };
            out.push(trace_record_for(i, &it.batch, sched, &sim, imem, &ctx));
        }
        for (d, sims) in sim.micro_batches.iter().enumerate() {
            for mbs in sims {
                for (j, &busy) in mbs.busy.iter().enumerate() {
                    rank_busy[d * cp + j] += busy;
                }
            }
        }
        for (g, &p) in imem.rank_peak_bytes.iter().enumerate() {
            if p > rank_peak[g] {
                rank_peak[g] = p;
            }
        }
        oom_events.extend(imem.events.iter().cloned());
        records.push(IterationRecord {
            exec_seconds: sim.total_time,
            grad_sync_seconds: sim.grad_sync,
            sched_seconds: it.sched_seconds,
            exposed_sched_seconds: 0.0, // finalized below, mode-dependent
            utilization: sim.compute_utilization,
            dp_imbalance: sim.dp_imbalance,
            micro_batches: it.micro_batches,
            data_tokens: it.data_tokens,
            padded_tokens: it.padded_tokens,
            bucket_tokens: it.bucket_tokens,
            peak_mem_fraction: mem.fraction_of_hbm(imem.peak_bytes()),
            rank_peak_bytes: imem.rank_peak_bytes.clone(),
            oom_events: imem.events.len(),
        });
    }

    // finalize exposed scheduling time: synchronous keeps everything on
    // the critical path; pipelined hides sched(i+1) behind exec(i), so
    // only the pipeline fill (iteration 0) and any sched time exceeding
    // the previous iteration's execution are exposed
    let mut prev_exec: Option<f64> = None;
    for rec in &mut records {
        rec.exposed_sched_seconds = match (built.mode, prev_exec) {
            (LoaderMode::Synchronous, _) | (LoaderMode::Pipelined, None) => rec.sched_seconds,
            (LoaderMode::Pipelined, Some(prev)) => (rec.sched_seconds - prev).max(0.0),
        };
        prev_exec = Some(rec.exec_seconds);
    }

    RunReport {
        dp,
        cp,
        bucket_size,
        mode: built.mode,
        exec_seconds: records.iter().map(|r| r.exec_seconds).sum(),
        sched_seconds: records.iter().map(|r| r.sched_seconds).sum(),
        exposed_sched_seconds: records.iter().map(|r| r.exposed_sched_seconds).sum(),
        data_tokens: records.iter().map(|r| r.data_tokens).sum(),
        padded_tokens: records.iter().map(|r| r.padded_tokens).sum(),
        bucket_tokens: records.iter().map(|r| r.bucket_tokens).sum(),
        iterations: records,
        rank_busy,
        capacity_source: built.capacity_source,
        hbm_bytes: mem.hbm_bytes,
        rank_peak_bytes: rank_peak,
        oom_events,
        sched_invocations: built.sched_invocations,
        drift_events: built.drift_events,
        peak_stream_rss_bytes: built.peak_stream_rss_bytes,
    }
}

/// Play `run.iterations` consecutive global batches from a fresh
/// [`ScheduledLoader`] over `ds` through the cost model — the one-shot
/// composition `price_run(build_run(..))`.
pub fn simulate_run(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    cost: &CostModel,
    run: &RunConfig,
) -> Result<RunReport, SchedError> {
    let built = build_run(ds, cfg, run)?;
    Ok(price_run(&built, cost, &built.topology))
}

/// [`simulate_run`] with the calibration trace emitter attached: alongside
/// the report, returns one [`TraceRecord`] per played iteration in the
/// `calib::trace` schema — the measurements a real cluster's profiler
/// would have produced for this run.
pub fn simulate_run_traced(
    ds: &Dataset,
    cfg: &ExperimentConfig,
    cost: &CostModel,
    run: &RunConfig,
) -> Result<(RunReport, Vec<TraceRecord>), SchedError> {
    let built = build_run(ds, cfg, run)?;
    Ok(price_run_traced(&built, cost, &built.topology))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::data::LengthDistribution;
    use crate::model::ModelSpec;

    fn setup(policy: Policy) -> (Dataset, ExperimentConfig, CostModel) {
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        cfg.policy = policy;
        cfg.cluster.batch_size = 16;
        let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 4_000, 11)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg.model);
        (ds, cfg, cost)
    }

    #[test]
    fn run_accumulates_iterations_and_invariants() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let run = RunConfig::new(4, true);
        let r = simulate_run(&ds, &cfg, &cost, &run).unwrap();
        assert_eq!(r.iterations.len(), 4);
        assert_eq!(r.bucket_size, cfg.bucket_size);
        assert_eq!(r.rank_busy.len(), cfg.cluster.dp * cfg.cluster.cp);
        for rec in &r.iterations {
            assert!((0.0..=1.0).contains(&rec.utilization));
            assert!(rec.grad_sync_seconds <= rec.exec_seconds);
        }
        assert!(r.exec_seconds > 0.0);
        assert!(r.sched_seconds > 0.0);
        assert!((0.0..=1.0).contains(&r.utilization()), "{}", r.utilization());
        assert!(r.effective_utilization() <= r.utilization() + 1e-15);
        assert!(r.mean_dp_imbalance() >= 1.0);
        assert!((0.0..=1.0).contains(&r.padding_fraction()));
        // exposed overhead can never exceed what was actually spent
        assert!(r.exposed_sched_seconds <= r.sched_seconds + 1e-15);
        assert!((r.wall_seconds() - (r.exec_seconds + r.exposed_sched_seconds)).abs() < 1e-12);
        // busy + idle = exec for every GPU
        for (b, i) in r.rank_busy.iter().zip(r.rank_idle()) {
            assert!((b + i - r.exec_seconds).abs() < 1e-9);
        }
        assert!(r.data_tokens > 0);
        // executed bucket tokens = data (shard-rounded up) + padding, so
        // they bound the raw data tokens from above
        assert!(r.bucket_tokens >= r.data_tokens + r.padded_tokens);
        // memory lane: peaks recorded per GPU, within budget on defaults
        assert_eq!(r.rank_peak_bytes.len(), cfg.cluster.dp * cfg.cluster.cp);
        let f = r.peak_mem_fraction();
        assert!(f > 0.0 && f <= 1.0, "peak fraction {f}");
        assert_eq!(r.oom_count(), 0);
        assert_eq!(r.capacity_source, crate::memplan::CapacitySource::Fixed);
        // one GDS/DACP pass per played iteration, no more
        assert_eq!(r.sched_invocations, 4);
        for rec in &r.iterations {
            assert!(rec.peak_mem_fraction > 0.0);
            assert_eq!(rec.rank_peak_bytes.len(), r.rank_peak_bytes.len());
            assert_eq!(rec.oom_events, 0);
        }
    }

    #[test]
    fn build_once_captures_schedules_and_counts_invocations() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let built = build_run(&ds, &cfg, &RunConfig::new(5, true)).unwrap();
        assert_eq!(built.iterations.len(), 5);
        // exactly one scheduling pass per iteration — the "no 2x work"
        // guarantee as an assertion
        assert_eq!(built.sched_invocations, 5);
        assert_eq!(built.dp, cfg.cluster.dp);
        assert_eq!(built.cp, cfg.cluster.cp);
        assert_eq!(built.bucket_size, cfg.bucket_size);
        assert_eq!(built.schedules().len(), 5);
        for it in &built.iterations {
            assert_eq!(it.batch.len(), cfg.cluster.batch_size);
            assert!(it.sched_seconds >= 0.0);
            let mut expect: Vec<u64> = it.batch.iter().map(|s| s.id).collect();
            expect.sort_unstable();
            assert_eq!(it.schedule.assigned_ids(), expect);
        }
        // pricing performs no scheduling: the counter is stable across
        // arbitrarily many pricings of the same built run
        let a = price_run(&built, &cost, &built.topology);
        let b = price_run(&built, &cost, &built.topology);
        assert_eq!(a.sched_invocations, 5);
        assert_eq!(b.sched_invocations, 5);
        assert_eq!(built.sched_invocations, 5);
    }

    #[test]
    fn pricing_is_pure_same_built_run_same_report() {
        let (ds, cfg, cost) = setup(Policy::SkrullRefined);
        let built = build_run(&ds, &cfg, &RunConfig::new(3, true)).unwrap();
        let a = price_run(&built, &cost, &built.topology);
        let b = price_run(&built, &cost, &built.topology);
        assert_eq!(a.exec_seconds, b.exec_seconds);
        assert_eq!(a.sched_seconds, b.sched_seconds);
        assert_eq!(a.exposed_sched_seconds, b.exposed_sched_seconds);
        assert_eq!(a.rank_busy, b.rank_busy);
        assert_eq!(a.rank_peak_bytes, b.rank_peak_bytes);
        assert_eq!(a.data_tokens, b.data_tokens);
        assert_eq!(a.padded_tokens, b.padded_tokens);
    }

    #[test]
    fn repricing_under_another_model_changes_exec_not_schedules() {
        // build once, price many: the same built run priced under a
        // degraded interconnect is strictly slower, with identical
        // scheduling accounting — no GDS/DACP rerun needed
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let built = build_run(&ds, &cfg, &RunConfig::new(3, false)).unwrap();
        let fast = price_run(&built, &cost, &built.topology);
        let slow_cost = cost.with_cross_node_cp();
        let slow = price_run(&built, &slow_cost, &built.topology);
        assert!(slow.exec_seconds > fast.exec_seconds);
        assert_eq!(slow.sched_seconds, fast.sched_seconds);
        assert_eq!(slow.data_tokens, fast.data_tokens);
        assert_eq!(slow.padded_tokens, fast.padded_tokens);
        assert_eq!(slow.sched_invocations, fast.sched_invocations);
        // memory is cost-model independent
        assert_eq!(slow.rank_peak_bytes, fast.rank_peak_bytes);
    }

    #[test]
    fn pricing_under_an_alternate_same_shape_topology_is_a_what_if() {
        // a 4x8 run can be priced on a hypothetical single fat node (same
        // dp×cp, different layout): the DP-group gradient sync drops from
        // IB to NVLink, so the what-if is strictly faster
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let built = build_run(&ds, &cfg, &RunConfig::new(2, false)).unwrap();
        let spread = price_run(&built, &cost, &built.topology);
        let fat = Topology::new(1, 32, cfg.cluster.dp, cfg.cluster.cp).unwrap();
        let contained = price_run(&built, &cost, &fat);
        assert!(contained.exec_seconds < spread.exec_seconds);
        assert_eq!(contained.data_tokens, spread.data_tokens);
    }

    #[test]
    #[should_panic(expected = "price_run: topology is")]
    fn pricing_under_a_mismatched_topology_shape_panics() {
        // a different dp×cp shape cannot place the built schedules; the
        // old engine would silently fall back to intra-node pricing
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let built = build_run(&ds, &cfg, &RunConfig::new(1, false)).unwrap();
        let other = Topology::new(4, 8, 2, 16).unwrap();
        let _ = price_run(&built, &cost, &other);
    }

    #[test]
    fn pinned_sched_seconds_make_reports_deterministic() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let mut b1 = build_run(&ds, &cfg, &RunConfig::new(3, true)).unwrap();
        let mut b2 = build_run(&ds, &cfg, &RunConfig::new(3, true)).unwrap();
        b1.pin_sched_seconds(1e-6);
        b2.pin_sched_seconds(1e-6);
        let r1 = price_run(&b1, &cost, &b1.topology);
        let r2 = price_run(&b2, &cost, &b2.topology);
        assert_eq!(r1.sched_seconds, r2.sched_seconds);
        assert_eq!(r1.exposed_sched_seconds, r2.exposed_sched_seconds);
        assert_eq!(r1.wall_seconds(), r2.wall_seconds());
        assert_eq!(r1.exec_seconds, r2.exec_seconds);
    }

    #[test]
    fn pipelined_run_matches_synchronous_schedules_and_hides_overhead() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let sync = simulate_run(&ds, &cfg, &cost, &RunConfig::new(5, false)).unwrap();
        let pipe = simulate_run(&ds, &cfg, &cost, &RunConfig::new(5, true)).unwrap();
        // identical workloads: execution accounting must match exactly
        assert_eq!(sync.iterations.len(), pipe.iterations.len());
        for (a, b) in sync.iterations.iter().zip(&pipe.iterations) {
            assert_eq!(a.exec_seconds, b.exec_seconds);
            assert_eq!(a.micro_batches, b.micro_batches);
            assert_eq!(a.data_tokens, b.data_tokens);
            assert_eq!(a.padded_tokens, b.padded_tokens);
        }
        assert_eq!(sync.rank_busy, pipe.rank_busy);
        // synchronous exposes every scheduling second; pipelined at most that
        assert!((sync.exposed_sched_seconds - sync.sched_seconds).abs() < 1e-15);
        assert!(pipe.exposed_sched_seconds <= pipe.sched_seconds + 1e-15);
        assert!(pipe.wall_seconds() <= sync.wall_seconds() + pipe.sched_seconds);
    }

    #[test]
    fn skrull_beats_baseline_end_to_end_on_bimodal_workload() {
        // the acceptance-criterion shape of the paper's Fig. 3: on a mixed
        // long/short distribution, Skrull's simulated end-to-end wall-clock
        // beats the DeepSpeed-like baseline
        let (ds, base_cfg, cost) = setup(Policy::Baseline);
        let run = RunConfig::new(5, true);
        let base = simulate_run(&ds, &base_cfg, &cost, &run).unwrap();
        let mut sk_cfg = base_cfg.clone();
        sk_cfg.policy = Policy::Skrull;
        let sk = simulate_run(&ds, &sk_cfg, &cost, &run).unwrap();
        let speedup = sk.speedup_over(&base);
        assert!(speedup > 1.0, "skrull speedup {speedup} ≤ 1.0");
        // and less padding waste (GDS packs instead of fixed micro-batching)
        assert!(sk.padding_fraction() <= base.padding_fraction() + 1e-12);
    }

    #[test]
    fn zero_iteration_run_is_empty_but_well_formed() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let r = simulate_run(&ds, &cfg, &cost, &RunConfig::new(0, true)).unwrap();
        assert!(r.iterations.is_empty());
        assert_eq!(r.wall_seconds(), 0.0);
        assert_eq!(r.utilization(), 0.0);
        assert_eq!(r.sched_overhead_fraction(), 0.0);
        assert_eq!(r.padding_fraction(), 0.0);
        assert_eq!(r.mean_dp_imbalance(), 1.0);
        assert_eq!(r.sched_invocations, 0);
    }

    #[test]
    fn epoch_run_plays_every_sequence_exactly_once() {
        use crate::data::LengthDistribution;
        let mut cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
        cfg.policy = Policy::Skrull;
        cfg.cluster.batch_size = 16;
        let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 100, 5)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        let cost = CostModel::paper_default(&cfg.model);
        let r = simulate_run(&ds, &cfg, &cost, &RunConfig::epoch(true)).unwrap();
        // ceil(100 / 16) batches, tail kept
        assert_eq!(r.iterations.len(), 7);
        assert_eq!(r.data_tokens, ds.total_tokens());
        // epoch runs schedule once per epoch batch
        assert_eq!(r.sched_invocations, 7);
        // pipelined and synchronous epoch runs agree on everything but
        // overhead exposure
        let s = simulate_run(&ds, &cfg, &cost, &RunConfig::epoch(false)).unwrap();
        assert_eq!(s.iterations.len(), r.iterations.len());
        for (a, b) in s.iterations.iter().zip(&r.iterations) {
            assert_eq!(a.exec_seconds, b.exec_seconds);
            assert_eq!(a.data_tokens, b.data_tokens);
            assert_eq!(a.micro_batches, b.micro_batches);
        }
        // and the epoch is seeded: same config, same batches
        let again = simulate_run(&ds, &cfg, &cost, &RunConfig::epoch(true)).unwrap();
        assert_eq!(again.data_tokens, r.data_tokens);
        assert_eq!(again.exec_seconds, r.exec_seconds);
    }

    #[test]
    fn lazy_epoch_build_matches_materialized_batches() {
        // Regression for the O(dataset) epoch materialization: the lazy
        // epoch_order + scratch-batch driver must reproduce the old
        // epoch_batches path byte for byte — batches, schedules, digests.
        let (ds, mut cfg, _cost) = setup(Policy::Skrull);
        cfg.cluster.batch_size = 16;
        let built = build_run(&ds, &cfg, &RunConfig::epoch(false)).unwrap();
        let batches = ds.epoch_batches(cfg.cluster.batch_size, cfg.seed);
        let resolved = cfg.resolve_capacity().unwrap();
        let mut old: Vec<(Vec<Sequence>, IterationSchedule)> = Vec::new();
        let mut loader = ScheduledLoader::new(&ds, &resolved);
        loader
            .run_synchronous_batches(&batches, |_, b, s, _| old.push((b.to_vec(), s.clone())))
            .unwrap();
        assert_eq!(built.iterations.len(), old.len());
        for (it, (b, s)) in built.iterations.iter().zip(&old) {
            assert_eq!(&it.batch, b);
            assert_eq!(&it.schedule, s);
        }
        // the digest sees the same bytes regardless of driver
        let again = build_run(&ds, &cfg, &RunConfig::epoch(true)).unwrap();
        assert_eq!(schedule_digest(&built), schedule_digest(&again));
        // in-memory builds carry zeroed stream accounting
        assert_eq!(built.drift_events, 0);
        assert_eq!(built.peak_stream_rss_bytes, 0);
    }

    #[test]
    fn undersized_hbm_flags_ooms_fixed_capacity_does_not_hide_them() {
        let (ds, mut cfg, cost) = setup(Policy::Baseline);
        // 4 GiB cannot hold a 26K-token bucket of the 0.5B model
        cfg.memory.hbm_gb = 4.0;
        let r = simulate_run(&ds, &cfg, &cost, &RunConfig::new(2, true)).unwrap();
        assert!(r.oom_count() > 0);
        assert!(r.peak_mem_fraction() > 1.0);
        // events carry coordinates inside the run
        for ev in &r.oom_events {
            assert!(ev.iteration < r.iterations.len());
            assert!(ev.dp_rank < r.dp && ev.cp_rank < r.cp);
            assert!(ev.peak_bytes > ev.hbm_bytes);
        }
    }

    #[test]
    fn oversubscribed_layout_is_rejected_not_silently_intra_node() {
        // Regression: an unplaceable dp×cp used to fall back to uniform
        // NVLink pricing via `.ok()`, reporting physically impossible
        // results without a word.
        let (ds, mut cfg, cost) = setup(Policy::Skrull);
        cfg.cluster.dp = 8; // 8×8 = 64 ranks on the 32-GPU testbed
        assert!(matches!(
            simulate_run(&ds, &cfg, &cost, &RunConfig::new(1, true)),
            Err(SchedError::BadTopology { .. })
        ));
        // the build phase rejects it too — there is nothing to price
        assert!(matches!(
            build_run(&ds, &cfg, &RunConfig::new(1, true)),
            Err(SchedError::BadTopology { .. })
        ));
    }

    #[test]
    fn hbm_derived_capacity_runs_oom_free() {
        use crate::memplan::CapacitySource;
        let (ds, mut cfg, cost) = setup(Policy::Skrull);
        cfg.memory.source = CapacitySource::HbmDerived;
        let r = simulate_run(&ds, &cfg, &cost, &RunConfig::new(3, true)).unwrap();
        assert_eq!(r.capacity_source, CapacitySource::HbmDerived);
        // the report carries the derived bucket, not the hand-set one
        assert_ne!(r.bucket_size, cfg.bucket_size);
        assert_eq!(r.bucket_size, cfg.mem_plan().derive_capacity().unwrap());
        assert_eq!(r.oom_count(), 0);
        let f = r.peak_mem_fraction();
        assert!(f > 0.0 && f <= 1.0, "peak fraction {f}");
        // infeasible budgets fail fast, before any scheduling
        cfg.memory.hbm_gb = 0.25;
        assert!(matches!(
            simulate_run(&ds, &cfg, &cost, &RunConfig::new(1, true)),
            Err(crate::scheduler::SchedError::NoCapacity { .. })
        ));
    }

    #[test]
    fn traced_run_emits_one_consistent_record_per_iteration() {
        let (ds, cfg, cost) = setup(Policy::Skrull);
        let run = RunConfig::new(4, false);
        let (report, records) = simulate_run_traced(&ds, &cfg, &cost, &run).unwrap();
        assert_eq!(records.len(), 4);
        // the traced run is the same run: execution accounting matches the
        // untraced engine exactly
        let plain = simulate_run(&ds, &cfg, &cost, &run).unwrap();
        assert_eq!(report.exec_seconds, plain.exec_seconds);
        assert_eq!(report.data_tokens, plain.data_tokens);
        for (i, (r, rec)) in records.iter().zip(&report.iterations).enumerate() {
            assert_eq!(r.iteration, i);
            assert_eq!(r.dp, cfg.cluster.dp);
            assert_eq!(r.cp, cfg.cluster.cp);
            assert_eq!(r.seq_lens.len(), cfg.cluster.batch_size);
            assert_eq!(
                r.seq_lens.iter().map(|&l| l as u64).sum::<u64>(),
                rec.data_tokens
            );
            assert_eq!(r.iteration_seconds, rec.exec_seconds);
            // every iteration computes and dispatches
            assert!(r.comp_flops > 0.0 && r.comp_kernels > 0.0 && r.comp_seconds > 0.0);
            assert!(r.dispatches > 0.0);
            // overhead is dispatches × the hardware's per-step floor
            let oh = r.overhead_seconds / r.dispatches;
            assert!((oh - cost.hw.step_overhead_s).abs() < 1e-15);
            // memory lane mirrors the report's iteration peaks
            assert!(r.bucket_tokens >= cfg.bucket_size as u64);
            let peak = rec.rank_peak_bytes.iter().copied().fold(0.0, f64::max);
            assert_eq!(r.peak_bytes, peak);
        }
        // <DP=4, CP=8> on the 4×8-node testbed: CP rings stay intra-node
        // (K/V exchanges land in comm_*), the DP group spans all four nodes
        // (the gradient reduce-scatter lands in xcomm_* each iteration)
        assert!(records.iter().all(|r| r.xcomm_launches >= 1.0));
        assert!(records.iter().any(|r| r.comm_launches > 0.0));
        let grad_bytes = cost.grad_sync_bytes(cfg.cluster.dp);
        for r in &records {
            assert!(r.xcomm_bytes >= grad_bytes);
        }
        // the traced pricing is the same pricing: price_run_traced on the
        // same built run reproduces both halves exactly
        let built = build_run(&ds, &cfg, &run).unwrap();
        let (rep2, recs2) = price_run_traced(&built, &cost, &built.topology);
        assert_eq!(rep2.exec_seconds, report.exec_seconds);
        for (a, b) in recs2.iter().zip(&records) {
            assert_eq!(a.comp_seconds, b.comp_seconds);
            assert_eq!(a.comm_seconds, b.comm_seconds);
            assert_eq!(a.xcomm_seconds, b.xcomm_seconds);
            assert_eq!(a.iteration_seconds, b.iteration_seconds);
        }
    }

    #[test]
    fn micro_batch_padding_counts_rank_buckets() {
        use crate::data::Sequence;
        use crate::scheduler::plan::{DacpPlan, DISTRIBUTED};
        let mb = MicroBatch {
            seqs: vec![
                Sequence { id: 0, len: 100 },
                Sequence { id: 1, len: 50 },
                Sequence { id: 2, len: 64 },
            ],
            plan: DacpPlan { assign: vec![0, 1, DISTRIBUTED] },
        };
        // cp=2, C=200: dist share = ceil(64/2) = 32 per rank
        // rank0: 100 + 32 = 132 used, 68 padded; rank1: 50 + 32 = 82, 118
        let (padded, bucket) = micro_batch_padding(&mb, 200, 2);
        assert_eq!(bucket, 400);
        assert_eq!(padded, 68 + 118);
    }
}
