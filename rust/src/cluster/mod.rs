//! Simulated distributed cluster: topology (DP×CP process groups over
//! nodes/GPUs) and the event-driven iteration simulator that plays an
//! `IterationSchedule` against the cost model.

pub mod sim;
pub mod topology;
pub mod trace;

pub use sim::{simulate_iteration, IterationSim, MicroBatchSim};
pub use topology::Topology;
