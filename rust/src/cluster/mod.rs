//! Simulated distributed cluster: topology (DP×CP process groups over
//! nodes/GPUs), the event-driven iteration simulator that plays an
//! `IterationSchedule` against the cost model, and the multi-iteration
//! run engine that turns per-iteration simulation into end-to-end
//! wall-clock (with pipelined scheduling overlap).

pub mod run;
pub mod sim;
pub mod topology;
pub mod trace;

pub use run::{
    build_run, build_run_streamed, price_run, price_run_traced, schedule_digest, simulate_run,
    simulate_run_traced, BatchSource, BuiltIteration, BuiltRun, IterationRecord, LoaderMode,
    RunConfig, RunReport,
};
pub use sim::{simulate_iteration, simulate_iteration_on, IterationSim, MicroBatchSim};
pub use topology::Topology;
