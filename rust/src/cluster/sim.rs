//! Event-driven iteration simulator.
//!
//! Plays an `IterationSchedule` against the cost model with the paper's
//! execution semantics:
//!   * within a micro-batch, each CP rank runs its local sequences while
//!     the CP collective for distributed sequences is in flight (Eq. 2
//!     overlap), then the distributed shards execute;
//!   * CP ranks synchronize at each micro-batch boundary (the attention
//!     collective is a group barrier);
//!   * DP ranks proceed independently through their micro-batch lists and
//!     meet at the gradient synchronization (Eq. 8 + ZeRO-2 reduce-scatter).
//!
//! Produces per-rank busy/idle traces for the utilization numbers in
//! EXPERIMENTS.md.

use crate::cluster::topology::Topology;
use crate::perfmodel::CostModel;
use crate::scheduler::plan::IterationSchedule;

/// Simulated timing of one micro-batch on one DP rank's CP group.
#[derive(Clone, Debug)]
pub struct MicroBatchSim {
    /// Eq. 1: makespan across the CP group.
    pub tdacp: f64,
    /// per-CP-rank busy compute time (local + dist, no comm wait)
    pub busy: Vec<f64>,
    /// exposed (un-overlapped) communication time per CP rank
    pub exposed_comm: Vec<f64>,
    pub num_distributed: usize,
    pub num_local: usize,
}

/// Simulated timing of one whole iteration.
#[derive(Clone, Debug)]
pub struct IterationSim {
    /// Eq. 8 + gradient sync.
    pub total_time: f64,
    /// per-DP-rank accumulated compute span (before grad sync)
    pub rank_spans: Vec<f64>,
    pub grad_sync: f64,
    pub micro_batches: Vec<Vec<MicroBatchSim>>,
    /// mean over GPUs of busy_compute / total_time
    pub compute_utilization: f64,
    /// makespan imbalance across DP ranks (max/mean)
    pub dp_imbalance: f64,
}

/// Simulate one micro-batch through Eq. 2.
pub fn simulate_micro_batch(
    lens: &[u32],
    plan: &crate::scheduler::plan::DacpPlan,
    cost: &CostModel,
    cp: usize,
) -> MicroBatchSim {
    let times = cost.rank_times(lens, plan, cp);
    let tdacp = times.iter().map(|t| t.total).fold(0.0, f64::max);
    MicroBatchSim {
        tdacp,
        busy: times.iter().map(|t| t.local_comp + t.dist_comp).collect(),
        exposed_comm: times
            .iter()
            .map(|t| (t.comm - t.local_comp).max(0.0))
            .collect(),
        num_distributed: plan.num_distributed(),
        num_local: lens.len() - plan.num_distributed(),
    }
}

/// Simulate a full iteration (Eq. 8–11 semantics).  `cp` is the job's
/// fixed context-parallel degree (N).  All CP groups are priced at
/// intra-node (NVLink) bandwidth; use [`simulate_iteration_on`] to charge
/// the actual topology.
pub fn simulate_iteration(sched: &IterationSchedule, cost: &CostModel, cp: usize) -> IterationSim {
    simulate_iteration_with(sched, cost, |_| None, cp)
}

/// Topology-aware iteration simulation: DP ranks whose CP group spans node
/// boundaries (`Topology::cp_group_crosses_nodes`) pay inter-node (IB)
/// bandwidth for their K/V exchanges, and a DP group that spans nodes
/// (`Topology::any_dp_group_crosses_nodes`) prices the gradient
/// reduce-scatter at IB too; the rest keep NVLink.  Identical to
/// [`simulate_iteration`] when nothing crosses.
pub fn simulate_iteration_on(
    sched: &IterationSchedule,
    cost: &CostModel,
    topo: &Topology,
) -> IterationSim {
    // cross-node DP only re-prices the gradient sync; per-rank compute and
    // K/V exchange times are unaffected by the flag
    let base = if topo.any_dp_group_crosses_nodes() {
        cost.with_cross_node_dp()
    } else {
        cost.clone()
    };
    let costs: Vec<Option<CostModel>> = (0..sched.ranks.len())
        .map(|d| {
            if topo.cp > 1 && d < topo.dp && topo.cp_group_crosses_nodes(d) {
                Some(cost.with_cross_node_cp())
            } else {
                None
            }
        })
        .collect();
    simulate_iteration_with(sched, &base, |d| costs[d].as_ref(), topo.cp)
}

/// Shared body: `cost_for(d)` overrides the cost model for DP rank `d`
/// (`None` = use `base`).  Gradient sync stays on `base` — ZeRO's
/// reduce-scatter runs over the DP group, whose pricing we keep uniform
/// (`base.cross_node_dp` decides NVLink vs IB for it).
fn simulate_iteration_with<'c, F>(
    sched: &IterationSchedule,
    base: &'c CostModel,
    cost_for: F,
    cp: usize,
) -> IterationSim
where
    F: Fn(usize) -> Option<&'c CostModel>,
{
    let dp = sched.ranks.len();
    let mut rank_spans = Vec::with_capacity(dp);
    let mut mbs_out = Vec::with_capacity(dp);
    for (d, rank) in sched.ranks.iter().enumerate() {
        let cost = cost_for(d).unwrap_or(base);
        let mut span = 0.0;
        let mut sims = Vec::with_capacity(rank.micro_batches.len());
        for mb in &rank.micro_batches {
            let sim = simulate_micro_batch(&mb.lens(), &mb.plan, cost, cp);
            span += sim.tdacp;
            sims.push(sim);
        }
        rank_spans.push(span);
        mbs_out.push(sims);
    }
    let cost = base;
    let slowest = rank_spans.iter().cloned().fold(0.0, f64::max);
    let grad_sync = cost.grad_sync_time(dp);
    let total = slowest + grad_sync;

    // utilization: mean busy compute over all CP ranks / total.  Every DP
    // rank owns `cp` GPUs whether or not it received micro-batches — an
    // idle rank's GPUs still burn the iteration, so they stay in the
    // denominator (a rank with zero micro-batches must *lower* utilization,
    // not vanish from it).
    let mut busy_total = 0.0;
    let gpu_count = dp * cp;
    for sims in &mbs_out {
        for sim in sims {
            busy_total += sim.busy.iter().sum::<f64>();
        }
    }
    let utilization = if total > 0.0 && gpu_count > 0 {
        busy_total / (gpu_count as f64 * total)
    } else {
        0.0
    };
    let mean_span = rank_spans.iter().sum::<f64>() / dp.max(1) as f64;
    let dp_imbalance = if mean_span > 0.0 { slowest / mean_span } else { 1.0 };

    IterationSim {
        total_time: total,
        rank_spans,
        grad_sync,
        micro_batches: mbs_out,
        compute_utilization: utilization,
        dp_imbalance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sequence;
    use crate::model::ModelSpec;
    use crate::perfmodel::CostModel;
    use crate::scheduler::plan::{DacpPlan, MicroBatch, RankSchedule, DISTRIBUTED};

    fn cm() -> CostModel {
        CostModel::paper_default(&ModelSpec::qwen2_5_0_5b())
    }

    fn mb(lens: &[u32], assign: Vec<i32>) -> MicroBatch {
        MicroBatch {
            seqs: lens
                .iter()
                .enumerate()
                .map(|(i, &len)| Sequence { id: i as u64, len })
                .collect(),
            plan: DacpPlan { assign },
        }
    }

    #[test]
    fn iteration_time_gated_by_slowest_dp_rank() {
        let cost = cm();
        let sched = IterationSchedule {
            ranks: vec![
                RankSchedule { micro_batches: vec![mb(&[30_000], vec![DISTRIBUTED])] },
                RankSchedule { micro_batches: vec![mb(&[100], vec![0])] },
            ],
        };
        let sim = simulate_iteration(&sched, &cost, 8);
        assert!(sim.rank_spans[0] > sim.rank_spans[1]);
        assert!((sim.total_time - (sim.rank_spans[0] + sim.grad_sync)).abs() < 1e-12);
        assert!(sim.dp_imbalance > 1.0);
    }

    #[test]
    fn utilization_higher_when_balanced() {
        let cost = cm();
        let unbalanced = IterationSchedule {
            ranks: vec![
                RankSchedule { micro_batches: vec![mb(&[8_000, 8_000], vec![0, 0])] },
                RankSchedule { micro_batches: vec![] },
            ],
        };
        let balanced = IterationSchedule {
            ranks: vec![
                RankSchedule { micro_batches: vec![mb(&[8_000], vec![0])] },
                RankSchedule { micro_batches: vec![mb(&[8_000], vec![0])] },
            ],
        };
        let u_un = simulate_iteration(&unbalanced, &cost, 1).compute_utilization;
        let u_ba = simulate_iteration(&balanced, &cost, 1).compute_utilization;
        assert!(u_ba > u_un, "balanced {u_ba} vs unbalanced {u_un}");
    }

    #[test]
    fn exposed_comm_shrinks_with_local_overlap() {
        let cost = cm();
        // distributed long seq alone: comm fully exposed on every rank
        let alone = simulate_micro_batch(
            &[20_000],
            &DacpPlan { assign: vec![DISTRIBUTED] },
            &cost,
            2,
        );
        // same + local work on rank 0: rank 0's comm partially hidden
        let overlapped = simulate_micro_batch(
            &[20_000, 15_000],
            &DacpPlan { assign: vec![DISTRIBUTED, 0] },
            &cost,
            2,
        );
        assert!(overlapped.exposed_comm[0] < alone.exposed_comm[0]);
        assert_eq!(alone.num_distributed, 1);
        assert_eq!(overlapped.num_local, 1);
    }

    #[test]
    fn empty_rank_counts_all_its_gpus() {
        // Regression: a DP rank with zero micro-batches used to contribute
        // one GPU to the utilization denominator instead of its cp GPUs,
        // inflating compute_utilization.
        let cost = cm();
        let cp = 4;
        let sched = IterationSchedule {
            ranks: vec![
                RankSchedule { micro_batches: vec![mb(&[8_000, 4_000], vec![0, 1])] },
                RankSchedule { micro_batches: vec![] },
            ],
        };
        let sim = simulate_iteration(&sched, &cost, cp);
        let busy_total: f64 = sim
            .micro_batches
            .iter()
            .flatten()
            .map(|s| s.busy.iter().sum::<f64>())
            .sum();
        // denominator must be dp*cp = 8 GPUs, not cp + 1 = 5
        let expect = busy_total / (8.0 * sim.total_time);
        assert!(
            (sim.compute_utilization - expect).abs() < 1e-12,
            "utilization {} != busy/(dp*cp*total) {}",
            sim.compute_utilization,
            expect
        );
        let inflated = busy_total / (5.0 * sim.total_time);
        assert!(sim.compute_utilization < inflated);
    }

    #[test]
    fn utilization_and_imbalance_invariants_hold_over_random_schedules() {
        // Property: for any schedulable workload, compute_utilization is in
        // [0, 1] and dp_imbalance >= 1.
        use crate::perfmodel::FlopsModel;
        use crate::scheduler::gds::{self, GdsConfig};
        use crate::util::proptest::{forall, SeqLensGen};

        let spec = ModelSpec::qwen2_5_0_5b();
        let cost = CostModel::paper_default(&spec);
        let flops = FlopsModel::new(&spec);
        let (dp, cp, bucket) = (4usize, 8usize, 16 * 1024u32);
        let gcfg = GdsConfig::new(bucket, cp, dp);
        let gen = SeqLensGen { min_k: 1, max_k: 48, max_len: bucket * cp as u32 };
        forall(0xE2E, 60, &gen, |lens| {
            let batch: Vec<Sequence> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| Sequence { id: i as u64, len })
                .collect();
            let sched = match gds::schedule(&batch, &gcfg, &flops) {
                Ok(s) => s,
                // only possible when a sequence exceeds C·N; not a sim bug
                Err(crate::scheduler::SchedError::TooLong { .. }) => return Ok(()),
                Err(e) => return Err(format!("schedule failed: {e}")),
            };
            let sim = simulate_iteration(&sched, &cost, cp);
            if !(0.0..=1.0).contains(&sim.compute_utilization) {
                return Err(format!("utilization {} out of [0,1]", sim.compute_utilization));
            }
            if sim.dp_imbalance < 1.0 {
                return Err(format!("dp_imbalance {} < 1", sim.dp_imbalance));
            }
            Ok(())
        });
    }

    #[test]
    fn cross_node_cp_group_slows_the_iteration() {
        // ROADMAP item made live: the same schedule on the same <DP=2,
        // CP=16> layout is strictly slower when the CP groups span two
        // 8-GPU nodes (paper testbed) than on hypothetical 16-GPU nodes —
        // and with ring attention, whose chunk chain multiplies the
        // per-step latency.
        use crate::cluster::topology::Topology;
        use crate::perfmodel::cost::CommPattern;

        let mut cost = cm();
        cost.pattern = CommPattern::Ring { cp: 16 };
        let mb_long = mb(&[60_000], vec![DISTRIBUTED]);
        let sched = IterationSchedule {
            ranks: vec![
                RankSchedule { micro_batches: vec![mb_long.clone()] },
                RankSchedule { micro_batches: vec![mb_long] },
            ],
        };
        let crossing = Topology::new(4, 8, 2, 16).unwrap();
        // a hypothetical single 32-GPU node: neither the CP rings nor the
        // DP group leave the NVLink domain
        let contained = Topology::new(1, 32, 2, 16).unwrap();
        assert!(crossing.cp_group_crosses_nodes(0));
        assert!(!contained.cp_group_crosses_nodes(0));
        assert!(!contained.any_dp_group_crosses_nodes());
        let t_cross = simulate_iteration_on(&sched, &cost, &crossing).total_time;
        let t_local = simulate_iteration_on(&sched, &cost, &contained).total_time;
        assert!(t_cross > t_local, "cross {t_cross} vs local {t_local}");
        // no crossing ⇒ exactly the plain simulator
        assert_eq!(t_local, simulate_iteration(&sched, &cost, 16).total_time);
    }

    #[test]
    fn cross_node_dp_group_pays_inter_node_grad_sync() {
        // ROADMAP item: the paper testbed's <DP=4, CP=8> keeps every CP
        // ring inside a node, but the DP peers sit one per node — the
        // gradient reduce-scatter must be priced at IB, not NVLink.
        use crate::cluster::topology::Topology;
        let cost = cm();
        let sched = IterationSchedule {
            ranks: (0..4)
                .map(|_| RankSchedule { micro_batches: vec![mb(&[4_000], vec![0])] })
                .collect(),
        };
        let spread = Topology::paper_testbed(4, 8).unwrap();
        let fat_node = Topology::new(1, 32, 4, 8).unwrap();
        assert!(spread.any_dp_group_crosses_nodes());
        assert!(!fat_node.any_dp_group_crosses_nodes());
        let s_cross = simulate_iteration_on(&sched, &cost, &spread);
        let s_local = simulate_iteration_on(&sched, &cost, &fat_node);
        // only the grad sync differs: compute spans are identical
        assert_eq!(s_cross.rank_spans, s_local.rank_spans);
        assert!(
            s_cross.grad_sync > s_local.grad_sync,
            "cross {} vs local {}",
            s_cross.grad_sync,
            s_local.grad_sync
        );
        assert!(s_cross.total_time > s_local.total_time);
        assert_eq!(s_cross.grad_sync, cost.with_cross_node_dp().grad_sync_time(4));
        assert_eq!(s_local.grad_sync, cost.grad_sync_time(4));
    }

    #[test]
    fn empty_schedule_costs_only_grad_sync() {
        let cost = cm();
        let sched = IterationSchedule {
            ranks: vec![RankSchedule::default(), RankSchedule::default()],
        };
        let sim = simulate_iteration(&sched, &cost, 8);
        assert!((sim.total_time - sim.grad_sync).abs() < 1e-15);
        assert_eq!(sim.compute_utilization, 0.0);
    }
}
