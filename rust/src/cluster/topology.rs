//! Cluster topology: nodes × GPUs arranged into DP × CP process groups,
//! mirroring the paper's testbed (4 nodes × 8 H100; CP groups within
//! NVLink domains where possible).

/// Physical + logical layout of one training job.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub dp: usize,
    pub cp: usize,
}

#[derive(Debug)]
pub enum TopologyError {
    TooManyRanks { need: usize, have: usize },
    BadCpDegree { cp: usize },
}

impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::TooManyRanks { need, have } => {
                write!(f, "dp*cp = {need} GPUs but cluster has {have}")
            }
            TopologyError::BadCpDegree { cp } => {
                write!(f, "cp degree {cp} must be a power of two")
            }
        }
    }
}

impl std::error::Error for TopologyError {}

/// Global GPU id of (dp_rank, cp_rank).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GpuId(pub usize);

impl Topology {
    /// The paper's testbed: 4 nodes × 8 GPUs.
    pub fn paper_testbed(dp: usize, cp: usize) -> Result<Self, TopologyError> {
        Self::new(4, 8, dp, cp)
    }

    pub fn new(nodes: usize, gpus_per_node: usize, dp: usize, cp: usize) -> Result<Self, TopologyError> {
        let have = nodes * gpus_per_node;
        let need = dp * cp;
        if need > have {
            return Err(TopologyError::TooManyRanks { need, have });
        }
        if !cp.is_power_of_two() {
            return Err(TopologyError::BadCpDegree { cp });
        }
        Ok(Topology { nodes, gpus_per_node, dp, cp })
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// GPUs are laid out CP-major so CP groups stay inside a node whenever
    /// cp ≤ gpus_per_node (NVLink domain), as real launchers do.
    pub fn gpu_of(&self, dp_rank: usize, cp_rank: usize) -> GpuId {
        assert!(dp_rank < self.dp && cp_rank < self.cp);
        GpuId(dp_rank * self.cp + cp_rank)
    }

    /// Does the CP group of `dp_rank` span node boundaries?  (If so, its
    /// collectives run at IB, not NVLink, bandwidth.)
    pub fn cp_group_crosses_nodes(&self, dp_rank: usize) -> bool {
        let first = self.gpu_of(dp_rank, 0).0 / self.gpus_per_node;
        let last = self.gpu_of(dp_rank, self.cp - 1).0 / self.gpus_per_node;
        first != last
    }

    /// Does the DP group of `cp_rank` span node boundaries?  DP peers sit
    /// at a `cp` GPU stride (CP-major layout), so the ZeRO reduce-scatter
    /// between them leaves the NVLink domain as soon as the dp·cp block
    /// outgrows one node.  GPU ids are monotone in dp_rank, so comparing
    /// the first and last member's node suffices.
    pub fn dp_group_crosses_nodes(&self, cp_rank: usize) -> bool {
        if self.dp <= 1 {
            return false;
        }
        let first = self.gpu_of(0, cp_rank).0 / self.gpus_per_node;
        let last = self.gpu_of(self.dp - 1, cp_rank).0 / self.gpus_per_node;
        first != last
    }

    /// Any DP group crossing a node boundary means the gradient
    /// reduce-scatter (one collective over all DP groups) pays inter-node
    /// bandwidth — the uniform pricing `CostModel::grad_sync_time` applies.
    pub fn any_dp_group_crosses_nodes(&self) -> bool {
        self.dp > 1 && (0..self.cp).any(|j| self.dp_group_crosses_nodes(j))
    }

    /// All (dp, cp) rank pairs.
    pub fn ranks(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.dp).flat_map(move |d| (0..self.cp).map(move |c| (d, c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_fits_dp4_cp8() {
        let t = Topology::paper_testbed(4, 8).unwrap();
        assert_eq!(t.total_gpus(), 32);
        assert_eq!(t.ranks().count(), 32);
        // CP groups of 8 fit in one 8-GPU node
        for d in 0..4 {
            assert!(!t.cp_group_crosses_nodes(d));
        }
    }

    #[test]
    fn dp2_cp16_crosses_nodes() {
        // the 7B+ChatQA2 setting <DP=2, CP=16> spans two nodes
        let t = Topology::paper_testbed(2, 16).unwrap();
        assert!(t.cp_group_crosses_nodes(0));
        assert!(t.cp_group_crosses_nodes(1));
    }

    #[test]
    fn rejects_oversubscription() {
        assert!(matches!(
            Topology::paper_testbed(8, 8),
            Err(TopologyError::TooManyRanks { need: 64, have: 32 })
        ));
    }

    #[test]
    fn rejects_non_power_of_two_cp() {
        assert!(matches!(
            Topology::paper_testbed(2, 6),
            Err(TopologyError::BadCpDegree { cp: 6 })
        ));
    }

    #[test]
    fn dp_groups_cross_nodes_on_the_paper_testbed() {
        // <DP=4, CP=8> on 4×8: DP peers of cp-rank j sit at gpus
        // {j, 8+j, 16+j, 24+j} — one per node, so the reduce-scatter
        // crosses nodes even though every CP ring is node-contained.
        let t = Topology::paper_testbed(4, 8).unwrap();
        for j in 0..8 {
            assert!(t.dp_group_crosses_nodes(j));
        }
        assert!(t.any_dp_group_crosses_nodes());
        // a single 32-GPU node contains everything
        let fat = Topology::new(1, 32, 4, 8).unwrap();
        assert!(!fat.any_dp_group_crosses_nodes());
        for j in 0..8 {
            assert!(!fat.dp_group_crosses_nodes(j));
        }
        // dp=1 has no gradient peers at all
        let solo = Topology::paper_testbed(1, 8).unwrap();
        assert!(!solo.any_dp_group_crosses_nodes());
    }

    #[test]
    fn gpu_ids_are_unique() {
        let t = Topology::paper_testbed(4, 8).unwrap();
        let mut ids: Vec<usize> = t.ranks().map(|(d, c)| t.gpu_of(d, c).0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 32);
    }
}
