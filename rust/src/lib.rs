//! Skrull: dynamic data scheduling for efficient long-context fine-tuning.
//!
//! Reproduction of "Skrull: Towards Efficient Long Context Fine-tuning
//! through Dynamic Data Scheduling" (NIPS 2025) as a three-layer
//! Rust + JAX + Pallas stack.  See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): the scheduler (GDS + DACP), performance model,
//!   cluster simulator, PJRT runtime and training coordinator.
//! * L2 (python/compile/model.py): the JAX train step, AOT-lowered to HLO.
//! * L1 (python/compile/kernels/): the Pallas packed flash-attention
//!   kernel the train step calls.

pub mod analysis;
pub mod bench;
pub mod calib;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod logging;
pub mod memplan;
pub mod model;
pub mod perfmodel;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod stream;
pub mod util;
