//! The daemon's control plane: flat JSONL records on stdin or a file,
//! one object per line, parsed with the calibration subsystem's
//! dependency-free flat-JSON reader.  Renderers for every record kind
//! live here too, and `parse_line(render_*(..))` round-trips exactly —
//! the recorded arrival logs that feed `serve --replay` are written and
//! read by this one module.
//!
//! Record kinds (discriminated by `"record"`):
//!   config    {"record": "config", "arrival": "...", "fleet_policy": "...",
//!              "pool_set": "...", "serial_scheduler": false,
//!              "tenant_weights": [..], "tenant_quotas": [..]}
//!   submit    {"record": "submit", "at": t, "id": n, "tenant": n,
//!              "dataset": "...", "dp": n, "cp": n, "batch_size": n,
//!              "iterations": n, "seq_count": n, "policy": "...",
//!              "priority": n, "seed": n}
//!   status    {"record": "status", "at": t}          (not journaled)
//!   node-loss {"record": "node-loss", "at": t, "pool": n, "nodes": n}
//!   drain     {"record": "drain", "at": t}
//!   shutdown  {"record": "shutdown", "at": t}
//!
//! JSON numbers are f64, so u64 seeds are masked to 2^53-1 when rendered
//! ([`SEED_MASK`]); both replay paths parse the same log, so byte-equality
//! of their reports is unaffected.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::calib::profile_io::{parse_object, Jval};
use crate::config::Policy;
use crate::fleet::job::FleetJob;
use crate::fleet::queue::FleetPolicy;
use crate::util::error::Result;

/// JSON carries numbers as f64: only seeds up to 2^53-1 survive the
/// round trip, so the log writer masks them down.
pub const SEED_MASK: u64 = (1 << 53) - 1;

/// The fleet configuration record, required first in every session.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigSpec {
    /// Label for the report cell (an `ArrivalPattern` name for recorded
    /// logs, but any label is accepted).
    pub arrival: String,
    pub fleet_policy: FleetPolicy,
    pub pool_set: String,
    pub serial_scheduler: bool,
    pub tenant_weights: Vec<f64>,
    pub tenant_quotas: Vec<usize>,
}

/// One parsed control record.
#[derive(Clone, Debug, PartialEq)]
pub enum ControlRecord {
    Config(ConfigSpec),
    Submit { at: f64, job: FleetJob },
    Status { at: f64 },
    NodeLoss { at: f64, pool: usize, nodes: usize },
    Drain { at: f64 },
    Shutdown { at: f64 },
}

/// Map a dataset name to the `&'static str` the fleet job carries
/// (`FleetJob.dataset` is static because workloads are usually
/// synthesized; the control plane and snapshot codec funnel through the
/// same statics).
pub(crate) fn static_dataset(name: &str) -> Result<&'static str> {
    match name {
        "wikipedia" => Ok("wikipedia"),
        "lmsys" => Ok("lmsys"),
        "chatqa2" => Ok("chatqa2"),
        other => crate::bail!("unknown dataset {other:?} (wikipedia | lmsys | chatqa2)"),
    }
}

fn get<'a>(obj: &'a BTreeMap<String, Jval>, key: &str) -> Result<&'a Jval> {
    obj.get(key).ok_or_else(|| crate::anyhow!("control record missing {key:?}"))
}

fn num(obj: &BTreeMap<String, Jval>, key: &str) -> Result<f64> {
    match get(obj, key)? {
        Jval::Num(x) => Ok(*x),
        other => crate::bail!("control field {key:?} is not a number: {other:?}"),
    }
}

fn uint(obj: &BTreeMap<String, Jval>, key: &str) -> Result<u64> {
    let x = num(obj, key)?;
    crate::ensure!(
        x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= SEED_MASK as f64,
        "control field {key:?} = {x} is not a non-negative integer"
    );
    Ok(x as u64)
}

fn string<'a>(obj: &'a BTreeMap<String, Jval>, key: &str) -> Result<&'a str> {
    match get(obj, key)? {
        Jval::Str(s) => Ok(s),
        other => crate::bail!("control field {key:?} is not a string: {other:?}"),
    }
}

fn boolean(obj: &BTreeMap<String, Jval>, key: &str) -> Result<bool> {
    match get(obj, key)? {
        Jval::Bool(b) => Ok(*b),
        other => crate::bail!("control field {key:?} is not a bool: {other:?}"),
    }
}

fn time(obj: &BTreeMap<String, Jval>) -> Result<f64> {
    let at = num(obj, "at")?;
    crate::ensure!(at.is_finite() && at >= 0.0, "control field \"at\" = {at} must be finite, >= 0");
    Ok(at)
}

/// Parse one control-plane line.
pub fn parse_line(line: &str) -> Result<ControlRecord> {
    let obj = parse_object(line.trim())?;
    let kind = string(&obj, "record")?;
    match kind {
        "config" => {
            let fleet_policy = {
                let name = string(&obj, "fleet_policy")?;
                FleetPolicy::by_name(name)
                    .ok_or_else(|| crate::anyhow!("unknown fleet policy {name:?}"))?
            };
            let weights = match get(&obj, "tenant_weights")? {
                Jval::Arr(xs) => xs.clone(),
                other => crate::bail!("tenant_weights is not an array: {other:?}"),
            };
            let quotas = match get(&obj, "tenant_quotas")? {
                Jval::Arr(xs) => xs
                    .iter()
                    .map(|&x| {
                        crate::ensure!(
                            x.is_finite() && x >= 1.0 && x.fract() == 0.0,
                            "tenant quota {x} is not a positive integer"
                        );
                        Ok(x as usize)
                    })
                    .collect::<Result<Vec<usize>>>()?,
                other => crate::bail!("tenant_quotas is not an array: {other:?}"),
            };
            crate::ensure!(
                !weights.is_empty() && weights.len() == quotas.len(),
                "config needs matching non-empty tenant_weights/tenant_quotas ({} vs {})",
                weights.len(),
                quotas.len()
            );
            crate::ensure!(
                weights.iter().all(|&w| w.is_finite() && w > 0.0),
                "tenant weights must be finite and positive"
            );
            Ok(ControlRecord::Config(ConfigSpec {
                arrival: string(&obj, "arrival")?.to_string(),
                fleet_policy,
                pool_set: string(&obj, "pool_set")?.to_string(),
                serial_scheduler: boolean(&obj, "serial_scheduler")?,
                tenant_weights: weights,
                tenant_quotas: quotas,
            }))
        }
        "submit" => {
            let at = time(&obj)?;
            let policy = {
                let name = string(&obj, "policy")?;
                Policy::by_name(name).ok_or_else(|| crate::anyhow!("unknown policy {name:?}"))?
            };
            let job = FleetJob {
                id: uint(&obj, "id")?,
                tenant: uint(&obj, "tenant")? as usize,
                dataset: static_dataset(string(&obj, "dataset")?)?,
                dp: uint(&obj, "dp")? as usize,
                cp: uint(&obj, "cp")? as usize,
                batch_size: uint(&obj, "batch_size")? as usize,
                iterations: uint(&obj, "iterations")? as usize,
                seq_count: uint(&obj, "seq_count")? as usize,
                policy,
                priority: uint(&obj, "priority")? as u32,
                submit_time: at,
                seed: uint(&obj, "seed")?,
            };
            crate::ensure!(
                job.dp >= 1 && job.cp >= 1 && job.iterations >= 1 && job.seq_count >= 1,
                "job {} has a zero shape field",
                job.id
            );
            Ok(ControlRecord::Submit { at, job })
        }
        "status" => Ok(ControlRecord::Status { at: time(&obj)? }),
        "node-loss" => Ok(ControlRecord::NodeLoss {
            at: time(&obj)?,
            pool: uint(&obj, "pool")? as usize,
            nodes: uint(&obj, "nodes")? as usize,
        }),
        "drain" => Ok(ControlRecord::Drain { at: time(&obj)? }),
        "shutdown" => Ok(ControlRecord::Shutdown { at: time(&obj)? }),
        other => crate::bail!("unknown control record kind {other:?}"),
    }
}

/// Render a config record (the exact line `parse_line` reads back).
pub fn render_config(spec: &ConfigSpec) -> String {
    let mut weights = String::new();
    for (i, w) in spec.tenant_weights.iter().enumerate() {
        let _ = write!(weights, "{}{}", if i == 0 { "" } else { ", " }, w);
    }
    let mut quotas = String::new();
    for (i, q) in spec.tenant_quotas.iter().enumerate() {
        let _ = write!(quotas, "{}{}", if i == 0 { "" } else { ", " }, q);
    }
    format!(
        "{{\"record\": \"config\", \"arrival\": \"{}\", \"fleet_policy\": \"{}\", \
         \"pool_set\": \"{}\", \"serial_scheduler\": {}, \
         \"tenant_weights\": [{}], \"tenant_quotas\": [{}]}}",
        spec.arrival,
        spec.fleet_policy.name(),
        spec.pool_set,
        spec.serial_scheduler,
        weights,
        quotas
    )
}

/// Render a submit record for `job` (seed masked to [`SEED_MASK`]).
pub fn render_submit(job: &FleetJob) -> String {
    format!(
        "{{\"record\": \"submit\", \"at\": {}, \"id\": {}, \"tenant\": {}, \
         \"dataset\": \"{}\", \"dp\": {}, \"cp\": {}, \"batch_size\": {}, \
         \"iterations\": {}, \"seq_count\": {}, \"policy\": \"{}\", \
         \"priority\": {}, \"seed\": {}}}",
        job.submit_time,
        job.id,
        job.tenant,
        job.dataset,
        job.dp,
        job.cp,
        job.batch_size,
        job.iterations,
        job.seq_count,
        job.policy.name(),
        job.priority,
        job.seed & SEED_MASK
    )
}

pub fn render_shutdown(at: f64) -> String {
    format!("{{\"record\": \"shutdown\", \"at\": {at}}}")
}

pub fn render_node_loss(at: f64, pool: usize, nodes: usize) -> String {
    format!("{{\"record\": \"node-loss\", \"at\": {at}, \"pool\": {pool}, \"nodes\": {nodes}}}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_config() -> ConfigSpec {
        ConfigSpec {
            arrival: "steady".to_string(),
            fleet_policy: FleetPolicy::Priority,
            pool_set: "hetero".to_string(),
            serial_scheduler: false,
            tenant_weights: vec![4.0, 2.0, 1.0, 1.0],
            tenant_quotas: vec![4, 3, 3, 2],
        }
    }

    #[test]
    fn config_round_trips() {
        let spec = sample_config();
        let line = render_config(&spec);
        match parse_line(&line).unwrap() {
            ControlRecord::Config(back) => assert_eq!(back, spec),
            other => panic!("expected config, got {other:?}"),
        }
    }

    #[test]
    fn submit_round_trips_with_masked_seed() {
        let job = FleetJob {
            id: 3,
            tenant: 1,
            dataset: "lmsys",
            dp: 2,
            cp: 8,
            batch_size: 16,
            iterations: 4,
            seq_count: 600,
            policy: Policy::Skrull,
            priority: 2,
            submit_time: 12.5,
            seed: u64::MAX, // masked on render
        };
        let line = render_submit(&job);
        match parse_line(&line).unwrap() {
            ControlRecord::Submit { at, job: back } => {
                assert_eq!(at, 12.5);
                assert_eq!(back.seed, u64::MAX & SEED_MASK);
                assert_eq!(back.dataset, "lmsys");
                assert_eq!(back.policy, Policy::Skrull);
                assert_eq!(back.dp, 2);
                assert_eq!(back.submit_time.to_bits(), job.submit_time.to_bits());
            }
            other => panic!("expected submit, got {other:?}"),
        }
    }

    #[test]
    fn times_render_shortest_round_trip_exact() {
        // Rust's {} Display for f64 is shortest-round-trip: the parsed
        // value is bit-identical to the rendered one, which is what makes
        // recorded logs a faithful arrival history
        for t in [0.0, 1.0 / 3.0, 1e-12, 98765.4321] {
            let line = render_shutdown(t);
            match parse_line(&line).unwrap() {
                ControlRecord::Shutdown { at } => assert_eq!(at.to_bits(), t.to_bits()),
                other => panic!("expected shutdown, got {other:?}"),
            }
        }
    }

    #[test]
    fn simple_records_parse() {
        assert_eq!(
            parse_line("{\"record\": \"status\", \"at\": 5}").unwrap(),
            ControlRecord::Status { at: 5.0 }
        );
        assert_eq!(
            parse_line(&render_node_loss(2.5, 1, 3)).unwrap(),
            ControlRecord::NodeLoss { at: 2.5, pool: 1, nodes: 3 }
        );
        assert_eq!(
            parse_line("{\"record\": \"drain\", \"at\": 0}").unwrap(),
            ControlRecord::Drain { at: 0.0 }
        );
    }

    #[test]
    fn malformed_records_are_structured_errors() {
        assert!(parse_line("not json").is_err());
        assert!(parse_line("{\"record\": \"launch-missiles\", \"at\": 0}").is_err());
        assert!(parse_line("{\"at\": 0}").is_err(), "missing record kind");
        assert!(parse_line("{\"record\": \"status\"}").is_err(), "missing at");
        assert!(parse_line("{\"record\": \"status\", \"at\": -1}").is_err(), "negative time");
        // bad config payloads
        let good = render_config(&sample_config());
        assert!(parse_line(&good.replace("priority", "lifo")).is_err(), "unknown policy");
        assert!(parse_line(&good.replace("[4, 3, 3, 2]", "[4, 3]")).is_err(), "quota mismatch");
        assert!(parse_line(&good.replace("[4, 3, 3, 2]", "[4, 3, 3, 0]")).is_err(), "zero quota");
        // bad submit payloads
        let job_line = "{\"record\": \"submit\", \"at\": 0, \"id\": 1, \"tenant\": 0, \
                        \"dataset\": \"wikipedia\", \"dp\": 1, \"cp\": 8, \"batch_size\": 8, \
                        \"iterations\": 2, \"seq_count\": 100, \"policy\": \"skrull\", \
                        \"priority\": 1, \"seed\": 5}";
        assert!(parse_line(job_line).is_ok());
        assert!(parse_line(&job_line.replace("wikipedia", "imagenet")).is_err());
        assert!(parse_line(&job_line.replace("\"dp\": 1", "\"dp\": 0")).is_err());
        assert!(parse_line(&job_line.replace("\"seed\": 5", "\"seed\": 2.5")).is_err());
    }
}
