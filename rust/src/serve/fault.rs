//! Deterministic fault injection for the serve daemon's journal I/O.
//!
//! A [`FaultPlan`] is a pure function of (seed, append index, attempt):
//! the same plan injects the same faults on every run, so every recovery
//! path in the tests is reproducible bit-for-bit.  Two fault families:
//!
//! - **Transient write errors**: an append attempt fails as if the disk
//!   hiccuped; the journal retries with bounded virtual-clock backoff.
//!   The schedule is seeded-pseudorandom but fails at most
//!   [`MAX_CONSECUTIVE_TRANSIENT`] attempts per append, so a bounded
//!   retry loop always lands the record.
//! - **Kill**: the process dies at the Nth append, leaving the record
//!   absent, half-written, or bit-flipped ([`TearMode`]) — the three
//!   tail states crash recovery must truncate away.
//!
//! Nothing here reads a wall clock or OS randomness.

use crate::util::error::Result;

/// Transient faults never repeat more than this many attempts in a row,
/// so `MAX_WRITE_ATTEMPTS` retries always suffice.
pub const MAX_CONSECUTIVE_TRANSIENT: u32 = 3;

/// How the kill fault leaves the tail record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TearMode {
    /// Process dies before any byte of the record lands.
    Clean,
    /// The first half of the record lands.
    Torn,
    /// The whole record lands with one payload bit flipped.
    BitFlip,
}

impl TearMode {
    pub const ALL: [TearMode; 3] = [TearMode::Clean, TearMode::Torn, TearMode::BitFlip];

    pub fn by_name(s: &str) -> Option<TearMode> {
        match s {
            "clean" => Some(TearMode::Clean),
            "torn" => Some(TearMode::Torn),
            "bitflip" => Some(TearMode::BitFlip),
            _ => None,
        }
    }
}

/// What the plan injects at one append attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    Transient,
    Kill(TearMode),
}

/// A seeded, deterministic fault schedule (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Kill the process at this append index (counting this process's
    /// appends from 0), tearing the record per the mode.
    pub kill_at: Option<(u64, TearMode)>,
    /// Roughly one in `transient_every` append attempts fails
    /// transiently; 0 disables transient faults.
    pub transient_every: u64,
}

/// splitmix64 — the same stateless mixer the repo's `Rng` seeds with;
/// used here so fault decisions are a pure hash of (seed, index, attempt).
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// No faults at all — production mode.
    pub fn none() -> FaultPlan {
        FaultPlan { seed: 0, kill_at: None, transient_every: 0 }
    }

    /// Kill at append `n` with the given tear; no transient faults.
    pub fn kill_at(n: u64, mode: TearMode) -> FaultPlan {
        FaultPlan { seed: 0, kill_at: Some((n, mode)), transient_every: 0 }
    }

    /// Frequent transient faults (about one attempt in three), no kill —
    /// exercises the retry/backoff path hard.
    pub fn transient_heavy(seed: u64) -> FaultPlan {
        FaultPlan { seed, kill_at: None, transient_every: 3 }
    }

    /// Parse a `--fault-plan` spec: comma-separated `key=value` pairs
    /// from `seed=N`, `kill=N:MODE` (mode `clean|torn|bitflip`), and
    /// `transient=N`.  `seed=7` alone means transient faults only.
    pub fn from_spec(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan { seed: 0, kill_at: None, transient_every: 0 };
        let mut saw_transient = false;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                crate::bail!("fault-plan part {part:?} is not key=value");
            };
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| crate::anyhow!("fault-plan seed {value:?} not a u64"))?;
                }
                "transient" => {
                    plan.transient_every = value
                        .parse()
                        .map_err(|_| crate::anyhow!("fault-plan transient {value:?} not a u64"))?;
                    saw_transient = true;
                }
                "kill" => {
                    let (n, mode) = match value.split_once(':') {
                        Some((n, m)) => (n, m),
                        None => (value, "clean"),
                    };
                    let n: u64 = n
                        .parse()
                        .map_err(|_| crate::anyhow!("fault-plan kill index {n:?} not a u64"))?;
                    let mode = TearMode::by_name(mode)
                        .ok_or_else(|| crate::anyhow!("unknown tear mode {mode:?}"))?;
                    plan.kill_at = Some((n, mode));
                }
                other => crate::bail!("unknown fault-plan key {other:?}"),
            }
        }
        // a bare seed means "inject the default transient schedule"
        if plan.seed != 0 && !saw_transient && plan.kill_at.is_none() {
            plan.transient_every = 4;
        }
        Ok(plan)
    }

    /// Decide the fault (if any) for append `index`, retry `attempt`.
    /// Pure: the same inputs always produce the same fault.
    pub fn on_append(&self, index: u64, attempt: u32) -> Option<Fault> {
        if let Some((kill, mode)) = self.kill_at {
            if index == kill {
                return Some(Fault::Kill(mode));
            }
        }
        if self.transient_every != 0
            && attempt < MAX_CONSECUTIVE_TRANSIENT
            && mix(self.seed ^ index.wrapping_mul(0x2545_F491_4F6C_DD1D) ^ attempt as u64)
                % self.transient_every
                == 0
        {
            return Some(Fault::Transient);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_bounded() {
        let plan = FaultPlan::transient_heavy(7);
        let mut any_transient = false;
        for index in 0..200u64 {
            // identical inputs, identical decisions
            assert_eq!(plan.on_append(index, 0), plan.on_append(index, 0));
            // attempts at/after the consecutive cap never fail
            assert_eq!(plan.on_append(index, MAX_CONSECUTIVE_TRANSIENT), None);
            if plan.on_append(index, 0) == Some(Fault::Transient) {
                any_transient = true;
            }
        }
        assert!(any_transient, "a heavy plan must actually inject faults");
    }

    #[test]
    fn kill_fires_at_exactly_one_index() {
        let plan = FaultPlan::kill_at(5, TearMode::Torn);
        for index in 0..10u64 {
            let fault = plan.on_append(index, 0);
            if index == 5 {
                assert_eq!(fault, Some(Fault::Kill(TearMode::Torn)));
            } else {
                assert_eq!(fault, None);
            }
        }
    }

    #[test]
    fn specs_parse_and_reject() {
        let p = FaultPlan::from_spec("seed=7").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.transient_every, 4, "bare seed implies the default transient schedule");
        let p = FaultPlan::from_spec("seed=3,kill=12:bitflip,transient=5").unwrap();
        assert_eq!(p.kill_at, Some((12, TearMode::BitFlip)));
        assert_eq!(p.transient_every, 5);
        let p = FaultPlan::from_spec("kill=2").unwrap();
        assert_eq!(p.kill_at, Some((2, TearMode::Clean)));
        assert_eq!(p.transient_every, 0, "a kill-only spec stays transient-free");
        assert!(FaultPlan::from_spec("seed").is_err());
        assert!(FaultPlan::from_spec("kill=2:melt").is_err());
        assert!(FaultPlan::from_spec("volts=9000").is_err());
        assert!(FaultPlan::from_spec("seed=banana").is_err());
    }

    #[test]
    fn empty_spec_is_no_faults() {
        let p = FaultPlan::from_spec("").unwrap();
        assert!(p.kill_at.is_none());
        assert_eq!(p.transient_every, 0);
        assert_eq!(p.on_append(0, 0), None);
    }
}
