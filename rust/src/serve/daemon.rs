//! The `skrull serve` daemon: the fleet core driven by a JSONL control
//! plane, with crash safety from the write-ahead journal and periodic
//! snapshots.
//!
//! Journal discipline, per journal-able control line:
//!   1. append the raw line as an `Input` record (write-ahead — the
//!      journal learns the input before the core does),
//!   2. apply it to the [`FleetCore`],
//!   3. append every [`FleetEvent`] the core decided as an `Event`
//!      record.
//! `status` lines are ephemeral (rendered from current state, never
//! journaled).  Every `snapshot_every` inputs the full core state is
//! snapshotted atomically and the journal truncated back to its header.
//!
//! Recovery = load the snapshot (if any) + replay the journal suffix.
//! Replayed `Input` records are re-applied to a fresh core; replayed
//! `Event` records are *byte-compared* against the events the core just
//! re-decided.  Any mismatch is fatal: the daemon must never out-decide
//! the simulator — the journal is a claim about what the deterministic
//! core did, and recovery re-proves it.  Events the crashed process
//! decided but never journaled are recomputed and appended; inputs it
//! journaled but the snapshot already absorbed are skipped by their
//! global input index (which also closes the save-snapshot-then-crash-
//! before-truncate window).
//!
//! Nothing here reads a wall clock; all fault handling is driven by the
//! seeded [`FaultPlan`] at the journal I/O boundary.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};

use crate::bench::fleet::render_cell_json;
use crate::fleet::job::{synthesize, ArrivalPattern, Tenant, Workload};
use crate::fleet::placement::ClusterSpec;
use crate::fleet::queue::FleetPolicy;
use crate::fleet::sim::{simulate, FleetCore, SimOptions};
use crate::serve::control::{self, ConfigSpec, ControlRecord};
use crate::serve::fault::{FaultPlan, TearMode};
use crate::serve::journal::{Journal, JournalError, RecordKind};
use crate::serve::snapshot;
use crate::util::error::{Context, Result};

/// Daemon knobs.
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// Where the journal (`fleet.journal`) and snapshot (`fleet.snap`)
    /// live; created if absent.
    pub state_dir: PathBuf,
    /// Snapshot (and truncate the journal) every this many absorbed
    /// inputs; 0 disables snapshotting and the journal grows unbounded.
    pub snapshot_every: usize,
    /// Fault injection at the journal I/O boundary; `FaultPlan::none()`
    /// in production.
    pub fault: FaultPlan,
}

/// How one daemon process ended.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// A shutdown record was processed; `cell_json` is the exact
    /// `BENCH_fleet.json` cell payload (`bench::fleet::render_cell_json`)
    /// — byte-identical to what `fleet::sim::simulate` emits for the
    /// same log.
    Completed { cell_json: String },
    /// The fault plan killed the process mid-append.  Re-running with
    /// the same state dir recovers and continues.
    Killed,
}

/// Live state once the config record has arrived.
struct DaemonState {
    config_line: String,
    arrival: String,
    pool_set: String,
    pool_gpus: usize,
    core: FleetCore,
}

fn tenants_of(spec: &ConfigSpec) -> Vec<Tenant> {
    spec.tenant_weights
        .iter()
        .zip(&spec.tenant_quotas)
        .enumerate()
        .map(|(id, (&weight, &quota))| Tenant { id, weight, quota })
        .collect()
}

impl DaemonState {
    fn build(spec: &ConfigSpec, line: &str) -> Result<DaemonState> {
        let cluster = ClusterSpec::by_name(&spec.pool_set)
            .ok_or_else(|| crate::anyhow!("unknown pool set {:?}", spec.pool_set))?;
        let pool_gpus = cluster.total_gpus();
        let opts = SimOptions {
            policy: spec.fleet_policy,
            cluster,
            serial_scheduler: spec.serial_scheduler,
        };
        let mut core = FleetCore::new(tenants_of(spec), opts);
        core.set_record_events(true);
        Ok(DaemonState {
            config_line: line.to_string(),
            arrival: spec.arrival.clone(),
            pool_set: spec.pool_set.clone(),
            pool_gpus,
            core,
        })
    }
}

fn require_state(state: &mut Option<DaemonState>) -> Result<&mut DaemonState> {
    state
        .as_mut()
        .ok_or_else(|| crate::anyhow!("control record arrived before the config record"))
}

/// Apply one journal-able control record.  Returns the rendered cell
/// payload when the record was a shutdown.
fn apply_record(
    state: &mut Option<DaemonState>,
    record: ControlRecord,
    line: &str,
) -> Result<Option<String>> {
    match record {
        ControlRecord::Config(spec) => {
            crate::ensure!(state.is_none(), "duplicate config record");
            *state = Some(DaemonState::build(&spec, line)?);
            Ok(None)
        }
        // status is never journaled, so it can only reach here through a
        // caller bug; applying it is a no-op either way
        ControlRecord::Status { .. } => Ok(None),
        ControlRecord::Submit { at, job } => {
            let st = require_state(state)?;
            st.core.step_until(at)?;
            st.core.submit(job, at)?;
            Ok(None)
        }
        ControlRecord::NodeLoss { at, pool, nodes } => {
            let st = require_state(state)?;
            st.core.step_until(at)?;
            st.core.lose_nodes(pool, nodes, at)?;
            Ok(None)
        }
        ControlRecord::Drain { at } => {
            let st = require_state(state)?;
            st.core.step_until(at)?;
            st.core.drain()?;
            Ok(None)
        }
        ControlRecord::Shutdown { .. } => {
            let st = require_state(state)?;
            st.core.drain()?;
            let report = st.core.finish_report()?;
            Ok(Some(render_cell_json(&st.arrival, &st.pool_set, st.pool_gpus, &report)))
        }
    }
}

/// Lift a journal call into the daemon's result space: a kill fault is a
/// clean `None` (the caller returns [`Outcome::Killed`]); everything else
/// converts to the crate error.
fn journal_step<T>(r: std::result::Result<T, JournalError>) -> Result<Option<T>> {
    match r {
        Ok(v) => Ok(Some(v)),
        Err(JournalError::Killed) => Ok(None),
        Err(e) => Err(crate::anyhow!("{e}")),
    }
}

/// Run the daemon over `lines`.  On a fresh state dir this processes the
/// control plane from the top; on a dir with a journal/snapshot it
/// recovers first (truncating any torn journal tail) and continues from
/// the first unabsorbed input.
pub fn run(lines: &[String], opts: &DaemonOptions) -> Result<Outcome> {
    std::fs::create_dir_all(&opts.state_dir)
        .with_context(|| format!("creating state dir {}", opts.state_dir.display()))?;
    let journal_path = opts.state_dir.join("fleet.journal");
    let snap_path = opts.state_dir.join("fleet.snap");

    let (suffix, mut journal) = if journal_path.exists() {
        match journal_step(Journal::recover(&journal_path, opts.fault))? {
            Some(pair) => pair,
            None => return Ok(Outcome::Killed),
        }
    } else {
        match journal_step(Journal::create(&journal_path, opts.fault))? {
            Some(j) => (Vec::new(), j),
            None => return Ok(Outcome::Killed),
        }
    };

    let mut state: Option<DaemonState> = None;
    let mut consumed: u64 = 0;
    if let Some(snap) = snapshot::load(&snap_path)? {
        let spec = match control::parse_line(&snap.config_line)? {
            ControlRecord::Config(spec) => spec,
            other => crate::bail!("snapshot config line is not a config record: {other:?}"),
        };
        let mut st = DaemonState::build(&spec, &snap.config_line)?;
        snap.apply(&mut st.core)?;
        consumed = snap.consumed_inputs;
        state = Some(st);
    }

    // replay the journal suffix: re-apply inputs, re-prove events
    let mut pending: VecDeque<Vec<u8>> = VecDeque::new();
    let mut done: Option<String> = None;
    // true while the events we are walking belong to an input the
    // snapshot already absorbed (the crash-between-save-and-truncate
    // window): their effects are in the snapshot, nothing to re-prove
    let mut absorbed = false;
    for rec in &suffix {
        match rec.kind {
            RecordKind::Input => {
                crate::ensure!(
                    rec.payload.len() >= 8,
                    "journal input record lacks its index prefix"
                );
                let mut idx = [0u8; 8];
                idx.copy_from_slice(&rec.payload[..8]);
                let idx = u64::from_le_bytes(idx);
                let line = std::str::from_utf8(&rec.payload[8..])
                    .map_err(|_| crate::anyhow!("journal input record is not utf-8"))?;
                if idx <= consumed {
                    absorbed = true;
                    continue;
                }
                absorbed = false;
                let record = control::parse_line(line)
                    .with_context(|| format!("replaying journal input {idx}"))?;
                let out = apply_record(&mut state, record, line)?;
                if out.is_some() {
                    done = out;
                }
                consumed = idx;
                if let Some(st) = state.as_mut() {
                    for ev in st.core.take_events() {
                        pending.push_back(ev.encode());
                    }
                }
            }
            RecordKind::Event => {
                if absorbed {
                    continue;
                }
                let Some(expected) = pending.pop_front() else {
                    crate::bail!(
                        "journal holds an event the replayed core never decided — \
                         the daemon out-decided the simulator"
                    );
                };
                crate::ensure!(
                    expected == rec.payload,
                    "journaled decision diverges from the replayed core — \
                     the daemon out-decided the simulator"
                );
            }
        }
    }
    // events the crashed process decided but never journaled: recomputed
    // above, appended now so the journal is whole again
    while let Some(ev) = pending.pop_front() {
        if journal_step(journal.append(RecordKind::Event, &ev))?.is_none() {
            return Ok(Outcome::Killed);
        }
    }
    if let Some(cell_json) = done {
        return Ok(Outcome::Completed { cell_json });
    }

    // continue the control plane past what the journal already absorbed
    let mut input_index: u64 = 0;
    let mut payload: Vec<u8> = Vec::with_capacity(256);
    for line in lines {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let record = control::parse_line(trimmed)
            .with_context(|| format!("control line {trimmed:?}"))?;
        if let ControlRecord::Status { at } = record {
            let (queued, running) = state
                .as_ref()
                .map_or((0, 0), |s| (s.core.queued_jobs(), s.core.running_jobs()));
            println!(
                "{{\"record\": \"status-report\", \"at\": {at}, \
                 \"queued\": {queued}, \"running\": {running}}}"
            );
            continue;
        }
        input_index += 1;
        if input_index <= consumed {
            continue; // absorbed before the crash (or by the snapshot)
        }
        // write-ahead: the journal learns the input before the core does
        payload.clear();
        payload.extend_from_slice(&input_index.to_le_bytes());
        payload.extend_from_slice(trimmed.as_bytes());
        if journal_step(journal.append(RecordKind::Input, &payload))?.is_none() {
            return Ok(Outcome::Killed);
        }
        let outcome = apply_record(&mut state, record, trimmed)?;
        if let Some(st) = state.as_mut() {
            for ev in st.core.take_events() {
                if journal_step(journal.append(RecordKind::Event, &ev.encode()))?.is_none() {
                    return Ok(Outcome::Killed);
                }
            }
        }
        consumed = input_index;
        if let Some(cell_json) = outcome {
            return Ok(Outcome::Completed { cell_json });
        }
        if opts.snapshot_every != 0 && consumed % opts.snapshot_every as u64 == 0 {
            if let Some(st) = state.as_ref() {
                snapshot::save(&snap_path, &st.core, &st.config_line, consumed)?;
                journal.truncate_to_header().map_err(|e| crate::anyhow!("{e}"))?;
            }
        }
    }
    crate::bail!("control input ended without a shutdown record")
}

/// Record a control-plane log for a synthesized workload: one config
/// line, one submit per job (seeds masked per `control::SEED_MASK`), and
/// a shutdown at the last arrival.  `serve --replay` on this log — via
/// the daemon or via the batch simulator — yields byte-identical cells.
pub fn record_log(
    pattern: ArrivalPattern,
    policy: FleetPolicy,
    pool_set: &str,
    n_jobs: usize,
    seed: u64,
) -> Result<Vec<String>> {
    crate::ensure!(n_jobs > 0, "a recorded log needs at least one job");
    crate::ensure!(
        ClusterSpec::by_name(pool_set).is_some(),
        "unknown pool set {pool_set:?}"
    );
    let workload = synthesize(pattern, n_jobs, seed);
    let spec = ConfigSpec {
        arrival: pattern.name().to_string(),
        fleet_policy: policy,
        pool_set: pool_set.to_string(),
        serial_scheduler: false,
        tenant_weights: workload.tenants.iter().map(|t| t.weight).collect(),
        tenant_quotas: workload.tenants.iter().map(|t| t.quota).collect(),
    };
    let mut lines = Vec::with_capacity(n_jobs + 2);
    lines.push(control::render_config(&spec));
    let mut last = 0.0f64;
    for job in &workload.jobs {
        last = last.max(job.submit_time);
        lines.push(control::render_submit(job));
    }
    lines.push(control::render_shutdown(last));
    Ok(lines)
}

/// Replay a recorded log through the batch simulator (`fleet::sim`) and
/// render the cell payload — the reference side of the CI `cmp` gate.
pub fn replay_via_sim(lines: &[String]) -> Result<String> {
    let mut spec: Option<ConfigSpec> = None;
    let mut jobs = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match control::parse_line(line)? {
            ControlRecord::Config(c) => {
                crate::ensure!(spec.is_none(), "replay log has two config records");
                spec = Some(c);
            }
            ControlRecord::Submit { job, .. } => jobs.push(job),
            ControlRecord::Shutdown { .. } => break,
            ControlRecord::Status { .. } | ControlRecord::Drain { .. } => {}
            ControlRecord::NodeLoss { .. } => {
                crate::bail!(
                    "the batch simulator cannot express node-loss records; \
                     replay this log through the daemon instead"
                )
            }
        }
    }
    let spec = spec.ok_or_else(|| crate::anyhow!("replay log has no config record"))?;
    let cluster = ClusterSpec::by_name(&spec.pool_set)
        .ok_or_else(|| crate::anyhow!("unknown pool set {:?}", spec.pool_set))?;
    let pool_gpus = cluster.total_gpus();
    let workload = Workload {
        // the label is carried verbatim into the cell; the pattern enum is
        // only used by synthesis, so any recorded label falls back safely
        pattern: ArrivalPattern::by_name(&spec.arrival).unwrap_or(ArrivalPattern::Steady),
        tenants: tenants_of(&spec),
        jobs,
    };
    let sim_opts = SimOptions {
        policy: spec.fleet_policy,
        cluster,
        serial_scheduler: spec.serial_scheduler,
    };
    let report = simulate(&workload, &sim_opts)?;
    Ok(render_cell_json(&spec.arrival, &spec.pool_set, pool_gpus, &report))
}

/// Replay a recorded log through a fault-free daemon in `state_dir` —
/// the daemon side of the CI `cmp` gate.
pub fn replay_via_daemon(lines: &[String], state_dir: &Path) -> Result<String> {
    let opts = DaemonOptions {
        state_dir: state_dir.to_path_buf(),
        snapshot_every: 0,
        fault: FaultPlan::none(),
    };
    match run(lines, &opts)? {
        Outcome::Completed { cell_json } => Ok(cell_json),
        Outcome::Killed => crate::bail!("a fault-free replay cannot be killed"),
    }
}

/// Drive the daemon to completion, restarting after each injected kill
/// (the restarted process drops the kill from its plan — a crash happens
/// once; transient faults keep firing).
pub fn run_to_completion(
    lines: &[String],
    state_dir: &Path,
    plan: FaultPlan,
    max_restarts: usize,
) -> Result<String> {
    let mut opts = DaemonOptions {
        state_dir: state_dir.to_path_buf(),
        snapshot_every: 3,
        fault: plan,
    };
    for _ in 0..=max_restarts {
        match run(lines, &opts)? {
            Outcome::Completed { cell_json } => return Ok(cell_json),
            Outcome::Killed => {
                opts.fault = FaultPlan {
                    seed: opts.fault.seed,
                    kill_at: None,
                    transient_every: opts.fault.transient_every,
                };
            }
        }
    }
    crate::bail!("daemon did not complete within {max_restarts} restarts")
}

/// CI smoke: record a small bursty log, replay it through the simulator
/// for the reference cell, then prove the daemon matches it byte-for-byte
/// under the given fault plan AND after a kill-and-recover cycle in every
/// tear mode.
pub fn run_smoke(plan: FaultPlan) -> Result<()> {
    let base = std::env::temp_dir().join(format!("skrull_serve_smoke_{}", std::process::id()));
    std::fs::create_dir_all(&base)?;
    let lines = record_log(ArrivalPattern::Bursty, FleetPolicy::Priority, "paper", 8, 11)?;
    let reference = replay_via_sim(&lines)?;
    let got = run_to_completion(&lines, &base.join("plan"), plan, 2)?;
    crate::ensure!(
        got == reference,
        "daemon under the fault plan diverged from the simulator"
    );
    println!("serve smoke: fault-plan run matches the simulator ({} bytes)", reference.len());
    for mode in TearMode::ALL {
        let dir = base.join(format!("kill_{mode:?}"));
        let kill = FaultPlan { seed: plan.seed, kill_at: Some((5, mode)), transient_every: 0 };
        let got = run_to_completion(&lines, &dir, kill, 2)?;
        crate::ensure!(
            got == reference,
            "recovery after a {mode:?} kill diverged from the simulator"
        );
        println!("serve smoke: {mode:?} kill at append 5 recovered byte-identical");
    }
    std::fs::remove_dir_all(&base).ok();
    println!("serve smoke passed: the daemon never out-decided the simulator");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::job::FleetJob;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("skrull_daemon_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn recorded_logs_replay_identically_via_sim_and_daemon() {
        let dir = tmp_dir("replay");
        let lines =
            record_log(ArrivalPattern::Steady, FleetPolicy::Fifo, "paper", 5, 3).unwrap();
        let via_sim = replay_via_sim(&lines).unwrap();
        let via_daemon = replay_via_daemon(&lines, &dir.join("d")).unwrap();
        assert_eq!(via_sim, via_daemon, "the daemon out-decided the simulator");
        // re-running on the same state dir recovers from the journal and
        // reproduces the identical cell without reprocessing the input
        let again = replay_via_daemon(&lines, &dir.join("d")).unwrap();
        assert_eq!(via_sim, again);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn kill_and_restart_recovers_byte_identical_with_snapshots() {
        let dir = tmp_dir("kill");
        let lines =
            record_log(ArrivalPattern::Steady, FleetPolicy::Fifo, "paper", 5, 3).unwrap();
        let reference = replay_via_sim(&lines).unwrap();
        for (i, mode) in TearMode::ALL.iter().enumerate() {
            let state = dir.join(format!("m{i}"));
            let plan = FaultPlan { seed: 0, kill_at: Some((7, *mode)), transient_every: 0 };
            let got = run_to_completion(&lines, &state, plan, 2).unwrap();
            assert_eq!(got, reference, "tear mode {mode:?} diverged after recovery");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn node_loss_logs_degrade_gracefully_through_the_daemon() {
        let dir = tmp_dir("loss");
        let mini = |id: u64, dp: usize| FleetJob {
            id,
            tenant: 0,
            dataset: "wikipedia",
            dp,
            cp: 8,
            batch_size: 8,
            iterations: 2,
            seq_count: 200,
            policy: crate::config::Policy::Skrull,
            priority: 1,
            submit_time: 0.0,
            seed: 5 + id,
        };
        let spec = ConfigSpec {
            arrival: "steady".to_string(),
            fleet_policy: FleetPolicy::Fifo,
            pool_set: "paper".to_string(),
            serial_scheduler: false,
            tenant_weights: vec![1.0],
            tenant_quotas: vec![10],
        };
        let lines = vec![
            control::render_config(&spec),
            control::render_submit(&mini(0, 4)),
            control::render_submit(&mini(1, 1)),
            control::render_node_loss(0.0, 0, 3),
            control::render_shutdown(0.0),
        ];
        let cell = replay_via_daemon(&lines, &dir.join("d")).unwrap();
        // the big job is evicted (its 4-node shape no longer fits), the
        // small one finishes on the survivor — degradation, not an error
        assert!(cell.contains("\"finished\": 1"), "{cell}");
        assert!(cell.contains("\"preemptions\": 1"), "{cell}");
        // and the batch simulator rightly refuses this log
        assert!(replay_via_sim(&lines).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn status_lines_are_ephemeral_and_malformed_input_is_fatal() {
        let dir = tmp_dir("status");
        let mut lines =
            record_log(ArrivalPattern::Steady, FleetPolicy::Fifo, "paper", 3, 9).unwrap();
        // status lines sprinkled anywhere must not change the outcome
        lines.insert(1, "{\"record\": \"status\", \"at\": 0}".to_string());
        lines.insert(3, "{\"record\": \"status\", \"at\": 1}".to_string());
        let with_status = replay_via_daemon(&lines, &dir.join("a")).unwrap();
        let without: Vec<String> =
            lines.iter().filter(|l| !l.contains("\"status\"")).cloned().collect();
        let plain = replay_via_daemon(&without, &dir.join("b")).unwrap();
        assert_eq!(with_status, plain);
        // a malformed line is a structured error, not a panic
        let mut bad = without;
        bad.insert(1, "{\"record\": \"launch-missiles\"}".to_string());
        assert!(replay_via_daemon(&bad, &dir.join("c")).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
