//! The daemon's write-ahead event journal: a header followed by
//! length-prefixed, sequence-numbered, FNV-1a-checksummed records, one
//! per control-plane input or fleet decision.  Append is write-ahead
//! (journal first, apply second) and fsyncs every record, so a crash can
//! lose at most the record being written — and recovery truncates that
//! torn tail back to the last valid record.
//!
//! Layout:
//!   header   magic "SKRLJRN\0" + version u32 + crc u64        20 bytes
//!   record   len u32 | seq u64 | kind u8 | payload | crc u64
//! where `len = 9 + payload.len()` (the seq+kind+payload span) and the
//! crc is FNV-1a over everything before it, len prefix included.
//!
//! Corruption policy: a record that fails to validate and *reaches the
//! end of the file* is a torn tail (the crash interrupted its write) —
//! recovery truncates it away.  The same failure mid-file, with valid
//! data after it, cannot be a crash artifact and is a hard
//! [`JournalError::Corrupt`].
//!
//! All faults are injected here, at the I/O boundary, by a seeded
//! [`FaultPlan`]: transient write errors get bounded retry with
//! virtual-clock backoff (a tick counter — nothing in `serve/` reads a
//! wall clock), and kill faults tear the record mid-write exactly like
//! a real crash.

use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::state::fnv1a;
use crate::serve::fault::{Fault, FaultPlan, TearMode};

pub const JOURNAL_MAGIC: [u8; 8] = *b"SKRLJRN\0";
pub const JOURNAL_VERSION: u32 = 1;
/// magic + version + header crc.
pub const HEADER_LEN: usize = 20;
/// len prefix + seq + kind before the payload, then the trailing crc.
pub const RECORD_OVERHEAD: usize = 4 + 8 + 1 + 8;
/// Upper bound on one record's payload (control lines and fleet events
/// are tiny; anything larger is corruption, not data).
pub const MAX_PAYLOAD: usize = 1 << 20;
/// Bounded retry budget for transient write faults.
pub const MAX_WRITE_ATTEMPTS: u32 = 8;

/// What one journal record carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecordKind {
    /// A raw control-plane line, journaled before it is applied.
    Input = 1,
    /// One `FleetEvent` encoding, journaled after the decision.
    Event = 2,
}

impl RecordKind {
    pub fn from_byte(b: u8) -> Option<RecordKind> {
        match b {
            1 => Some(RecordKind::Input),
            2 => Some(RecordKind::Event),
            _ => None,
        }
    }
}

/// One decoded journal record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub seq: u64,
    pub kind: RecordKind,
    pub payload: Vec<u8>,
}

/// Structured journal failure — never a panic.
#[derive(Debug)]
pub enum JournalError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadHeaderChecksum,
    /// Unrecoverable mid-file damage (valid records follow the bad one),
    /// or a daemon decision that disagrees with the journaled history.
    Corrupt { offset: usize, reason: &'static str },
    /// The fault plan killed the process at this append; the record at
    /// the tail may be torn.
    Killed,
}

impl fmt::Display for JournalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JournalError::Io(e) => write!(f, "journal io: {e}"),
            JournalError::BadMagic => write!(f, "journal has wrong magic"),
            JournalError::BadVersion(v) => write!(f, "unsupported journal version {v}"),
            JournalError::BadHeaderChecksum => write!(f, "journal header checksum mismatch"),
            JournalError::Corrupt { offset, reason } => {
                write!(f, "journal corrupt at byte {offset}: {reason}")
            }
            JournalError::Killed => write!(f, "fault plan killed the daemon mid-append"),
        }
    }
}

impl std::error::Error for JournalError {}

impl From<std::io::Error> for JournalError {
    fn from(e: std::io::Error) -> Self {
        JournalError::Io(e)
    }
}

/// Encode one record (header-less) into `buf`, which is cleared first.
pub fn encode_record_into(buf: &mut Vec<u8>, seq: u64, kind: RecordKind, payload: &[u8]) {
    buf.clear();
    let len = (9 + payload.len()) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.push(kind as u8);
    buf.extend_from_slice(payload);
    let crc = fnv1a(buf);
    buf.extend_from_slice(&crc.to_le_bytes());
}

/// Strictly decode exactly one record occupying all of `bytes` — used by
/// the mutation-sweep hardening test.  The streaming reader below uses
/// the same field layout but handles trailing data itself.
pub fn decode_record(bytes: &[u8]) -> Result<Record, JournalError> {
    if bytes.len() < RECORD_OVERHEAD {
        return Err(JournalError::Corrupt { offset: 0, reason: "record shorter than overhead" });
    }
    let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
    if len < 9 || len > MAX_PAYLOAD + 9 {
        return Err(JournalError::Corrupt { offset: 0, reason: "record length out of range" });
    }
    if bytes.len() != 4 + len + 8 {
        return Err(JournalError::Corrupt { offset: 0, reason: "record length disagrees" });
    }
    let body = &bytes[..4 + len];
    let mut crc = [0u8; 8];
    crc.copy_from_slice(&bytes[4 + len..]);
    if fnv1a(body) != u64::from_le_bytes(crc) {
        return Err(JournalError::Corrupt { offset: 0, reason: "record checksum mismatch" });
    }
    let mut seq = [0u8; 8];
    seq.copy_from_slice(&bytes[4..12]);
    let kind = RecordKind::from_byte(bytes[12])
        .ok_or(JournalError::Corrupt { offset: 12, reason: "unknown record kind" })?;
    Ok(Record {
        seq: u64::from_le_bytes(seq),
        kind,
        payload: bytes[13..4 + len].to_vec(),
    })
}

fn encode_header() -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(&JOURNAL_MAGIC);
    h[8..12].copy_from_slice(&JOURNAL_VERSION.to_le_bytes());
    let crc = fnv1a(&h[..12]);
    h[12..].copy_from_slice(&crc.to_le_bytes());
    h
}

/// Parse a journal image: header, then records.  Returns the records and
/// the byte length of the valid prefix; a torn tail (any failure that
/// reaches the end of the image) is *reported by a shorter valid length*,
/// not an error.  Mid-file damage is [`JournalError::Corrupt`].
pub fn parse_image(bytes: &[u8]) -> Result<(Vec<Record>, usize), JournalError> {
    if bytes.len() < HEADER_LEN {
        return Err(JournalError::BadMagic);
    }
    if bytes[..8] != JOURNAL_MAGIC {
        return Err(JournalError::BadMagic);
    }
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    let mut crc = [0u8; 8];
    crc.copy_from_slice(&bytes[12..20]);
    if fnv1a(&bytes[..12]) != u64::from_le_bytes(crc) {
        return Err(JournalError::BadHeaderChecksum);
    }
    if version != JOURNAL_VERSION {
        return Err(JournalError::BadVersion(version));
    }
    let mut records = Vec::new();
    let mut off = HEADER_LEN;
    while off < bytes.len() {
        let remaining = bytes.len() - off;
        // a record that cannot even state its length is a torn tail
        if remaining < 4 {
            return Ok((records, off));
        }
        let len =
            u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
                as usize;
        let total = 4 + len + 8;
        if len < 9 || len > MAX_PAYLOAD + 9 || total > remaining {
            // an absurd or overlong length that extends to/past EOF is a
            // torn tail; an absurd length with valid data after it cannot
            // be distinguished, so the conservative call is torn only when
            // the claimed span leaves nothing after it
            if total > remaining {
                return Ok((records, off));
            }
            return Err(JournalError::Corrupt { offset: off, reason: "record length out of range" });
        }
        match decode_record(&bytes[off..off + total]) {
            Ok(rec) => {
                let expected = records.len() as u64;
                if rec.seq != expected {
                    return Err(JournalError::Corrupt {
                        offset: off,
                        reason: "record sequence number out of order",
                    });
                }
                records.push(rec);
                off += total;
            }
            Err(_) if off + total == bytes.len() => {
                // checksum failure on the very last record: a torn or
                // bit-flipped tail from the crash — truncate it away
                return Ok((records, off));
            }
            Err(e) => return Err(e),
        }
    }
    Ok((records, off))
}

/// The append half: an open journal file plus the fault-injection plan.
pub struct Journal {
    path: PathBuf,
    file: File,
    /// Next sequence number to append (== records currently on disk).
    pub next_seq: u64,
    /// Reusable record scratch — `append` is on the fleet hot path and
    /// must not allocate per record.
    scratch: Vec<u8>,
    fault: FaultPlan,
    /// Appends performed by this process — the fault plan's kill index
    /// counts these, not `next_seq`, which resets on snapshot truncation.
    appended_total: u64,
    /// Accumulated virtual backoff from transient-fault retries.  Purely
    /// simulated (a tick counter): `serve/` never sleeps and never reads
    /// a wall clock.
    pub backoff_ticks: u64,
}

impl Journal {
    /// Create a fresh journal (truncating any prior file), write and
    /// fsync the header, and fsync the parent directory so the file
    /// itself survives a crash.
    pub fn create(path: &Path, fault: FaultPlan) -> Result<Journal, JournalError> {
        let mut file = File::create(path)?;
        file.write_all(&encode_header())?;
        file.sync_all()?;
        crate::util::fsio::fsync_dir(path)?;
        Ok(Journal {
            path: path.to_path_buf(),
            file,
            next_seq: 0,
            scratch: Vec::with_capacity(256),
            fault,
            appended_total: 0,
            backoff_ticks: 0,
        })
    }

    /// Recover an existing journal: parse it, truncate any torn tail back
    /// to the last valid record (fsyncing the truncation), and return the
    /// surviving records plus an append-ready handle.
    pub fn recover(path: &Path, fault: FaultPlan) -> Result<(Vec<Record>, Journal), JournalError> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        let (records, valid_len) = parse_image(&bytes)?;
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        if valid_len < bytes.len() {
            file.set_len(valid_len as u64)?;
            file.sync_all()?;
        }
        let mut file = file;
        file.seek(SeekFrom::End(0))?;
        let next_seq = records.len() as u64;
        Ok((
            records,
            Journal {
                path: path.to_path_buf(),
                file,
                next_seq,
                scratch: Vec::with_capacity(256),
                fault,
                appended_total: 0,
                backoff_ticks: 0,
            },
        ))
    }

    /// Append one record write-ahead: encode into the reusable scratch,
    /// push through the fault plan (bounded retry with virtual backoff on
    /// transient faults; a kill fault tears the record and dies), write,
    /// fsync.  Returns the record's sequence number.
    ///
    /// Hot path: one fsync is inherent to write-ahead durability, but the
    /// encode itself reuses `self.scratch` and allocates nothing.
    pub fn append(&mut self, kind: RecordKind, payload: &[u8]) -> Result<u64, JournalError> {
        let seq = self.next_seq;
        encode_record_into(&mut self.scratch, seq, kind, payload);
        let mut attempt: u32 = 0;
        loop {
            match self.fault.on_append(self.appended_total, attempt) {
                Some(Fault::Transient) => {
                    attempt += 1;
                    if attempt >= MAX_WRITE_ATTEMPTS {
                        return Err(JournalError::Io(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "transient write retries exhausted",
                        )));
                    }
                    // exponential virtual backoff: a tick counter, never a
                    // sleep or a clock read
                    self.backoff_ticks += 1u64 << attempt.min(16);
                }
                Some(Fault::Kill(mode)) => {
                    self.tear(mode)?;
                    return Err(JournalError::Killed);
                }
                None => break,
            }
        }
        self.file.write_all(&self.scratch)?;
        self.file.sync_all()?;
        self.next_seq = seq + 1;
        self.appended_total += 1;
        Ok(seq)
    }

    /// Simulate the crash the fault plan asked for: leave the record
    /// absent (`Clean`), half-written (`Torn`), or fully written with one
    /// bit flipped (`BitFlip`) — the three tail states recovery must
    /// truncate away.
    fn tear(&mut self, mode: TearMode) -> Result<(), JournalError> {
        match mode {
            TearMode::Clean => {}
            TearMode::Torn => {
                let half = self.scratch.len() / 2;
                self.file.write_all(&self.scratch[..half])?;
                self.file.sync_all()?;
            }
            TearMode::BitFlip => {
                let mid = self.scratch.len() / 2;
                self.scratch[mid] ^= 0x10;
                self.file.write_all(&self.scratch)?;
                self.file.sync_all()?;
            }
        }
        Ok(())
    }

    /// Drop every record after a snapshot has captured their effects:
    /// truncate back to the bare header, fsync, and reset the sequence
    /// numbering (the snapshot records how many inputs it absorbed).
    pub fn truncate_to_header(&mut self) -> Result<(), JournalError> {
        self.file.set_len(HEADER_LEN as u64)?;
        self.file.seek(SeekFrom::End(0))?;
        self.file.sync_all()?;
        crate::util::fsio::fsync_dir(&self.path)?;
        self.next_seq = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("skrull_jrn_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn records_round_trip_through_the_file() {
        let dir = tmp_dir("rt");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::none()).unwrap();
        assert_eq!(j.append(RecordKind::Input, b"{\"record\": \"submit\"}").unwrap(), 0);
        assert_eq!(j.append(RecordKind::Event, &[4, 1, 2, 3]).unwrap(), 1);
        assert_eq!(j.append(RecordKind::Event, b"").unwrap(), 2);
        drop(j);
        let (records, j2) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(j2.next_seq, 3);
        assert_eq!(records.len(), 3);
        assert_eq!(records[0].kind, RecordKind::Input);
        assert_eq!(records[0].payload, b"{\"record\": \"submit\"}");
        assert_eq!(records[1].payload, vec![4, 1, 2, 3]);
        assert!(records[2].payload.is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_to_the_last_valid_record() {
        let dir = tmp_dir("torn");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::none()).unwrap();
        j.append(RecordKind::Input, b"one").unwrap();
        j.append(RecordKind::Input, b"two").unwrap();
        drop(j);
        // chop mid-record: simulate a crash during the third append
        let mut bytes = std::fs::read(&path).unwrap();
        let full = bytes.len();
        let mut scratch = Vec::new();
        encode_record_into(&mut scratch, 2, RecordKind::Event, b"partial");
        bytes.extend_from_slice(&scratch[..scratch.len() / 2]);
        std::fs::write(&path, &bytes).unwrap();
        let (records, j2) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(j2.next_seq, 2);
        assert_eq!(std::fs::metadata(&path).unwrap().len() as usize, full);
        // recovery is idempotent: a second pass sees a clean file
        drop(j2);
        let (records, _) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bitflipped_tail_truncates_but_midfile_flip_is_corrupt() {
        let dir = tmp_dir("flip");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::none()).unwrap();
        j.append(RecordKind::Input, b"aaaa").unwrap();
        let tail_start = std::fs::metadata(&path).unwrap().len() as usize;
        j.append(RecordKind::Input, b"bbbb").unwrap();
        drop(j);
        // flip a payload bit in the LAST record: torn tail, truncated away
        let clean = std::fs::read(&path).unwrap();
        let mut bytes = clean.clone();
        bytes[tail_start + 13] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        let (records, _) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 1, "flipped tail record must be dropped");
        // flip a bit in the FIRST record while a valid one follows:
        // unrecoverable mid-file corruption
        let mut bytes = clean;
        bytes[HEADER_LEN + 13] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        match Journal::recover(&path, FaultPlan::none()) {
            Err(JournalError::Corrupt { .. }) => {}
            other => panic!("mid-file corruption must be fatal, got {other:?}"),
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn header_damage_is_structured() {
        let dir = tmp_dir("hdr");
        let path = dir.join("j.log");
        drop(Journal::create(&path, FaultPlan::none()).unwrap());
        let clean = std::fs::read(&path).unwrap();
        let mut bytes = clean.clone();
        bytes[0] = b'X';
        assert!(matches!(parse_image(&bytes), Err(JournalError::BadMagic)));
        let mut bytes = clean.clone();
        bytes[8] = 9;
        assert!(matches!(parse_image(&bytes), Err(JournalError::BadHeaderChecksum)));
        // a version bump with a recomputed crc is BadVersion
        let mut bytes = clean;
        bytes[8] = 9;
        let crc = crate::coordinator::state::fnv1a(&bytes[..12]);
        bytes[12..20].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(parse_image(&bytes), Err(JournalError::BadVersion(9))));
        assert!(matches!(parse_image(b"tiny"), Err(JournalError::BadMagic)));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn record_codec_survives_exhaustive_mutation() {
        // the satellite-2 sweep, reused for the journal record codec:
        // every bit flip, truncation and garbage buffer must be rejected
        let mut valid = Vec::new();
        encode_record_into(&mut valid, 3, RecordKind::Event, &[7, 7, 7, 0, 255]);
        // decode_record requires seq to be embedded consistently, but the
        // strict decoder does not know the expected seq — wrap it so any
        // accepted mutant must still be the original record
        let reference = decode_record(&valid).unwrap();
        crate::util::proptest::assert_codec_rejects_mutants(&valid, 256, 17, |bytes| {
            match decode_record(bytes) {
                Ok(r) if r == reference => Ok(r),
                Ok(_) => Err(JournalError::Corrupt { offset: 0, reason: "mutant decoded" }),
                Err(e) => Err(e),
            }
        });
    }

    #[test]
    fn sequence_gaps_are_corrupt() {
        let dir = tmp_dir("seq");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::none()).unwrap();
        j.append(RecordKind::Input, b"zero").unwrap();
        drop(j);
        let mut bytes = std::fs::read(&path).unwrap();
        let mut scratch = Vec::new();
        // seq jumps from 0 to 5: a spliced journal, not a crash artifact —
        // but only detectable as corrupt when valid data follows, so give
        // it a valid successor
        encode_record_into(&mut scratch, 5, RecordKind::Input, b"five");
        bytes.extend_from_slice(&scratch);
        encode_record_into(&mut scratch, 6, RecordKind::Input, b"six");
        bytes.extend_from_slice(&scratch);
        match parse_image(&bytes) {
            Err(JournalError::Corrupt { reason, .. }) => {
                assert!(reason.contains("sequence"), "{reason}");
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncate_to_header_resets_the_log() {
        let dir = tmp_dir("trunc");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::none()).unwrap();
        j.append(RecordKind::Input, b"gone").unwrap();
        j.truncate_to_header().unwrap();
        assert_eq!(j.next_seq, 0);
        j.append(RecordKind::Input, b"kept").unwrap();
        drop(j);
        let (records, _) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].payload, b"kept");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn transient_faults_retry_with_virtual_backoff() {
        let dir = tmp_dir("transient");
        let path = dir.join("j.log");
        let mut j = Journal::create(&path, FaultPlan::transient_heavy(7)).unwrap();
        for i in 0..32u8 {
            j.append(RecordKind::Event, &[i]).unwrap();
        }
        assert!(j.backoff_ticks > 0, "a heavy transient plan must trigger retries");
        drop(j);
        let (records, _) = Journal::recover(&path, FaultPlan::none()).unwrap();
        assert_eq!(records.len(), 32, "every append must eventually land");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn kill_fault_tears_the_tail_exactly_once() {
        for mode in [TearMode::Clean, TearMode::Torn, TearMode::BitFlip] {
            let dir = tmp_dir("kill");
            let path = dir.join(format!("j_{mode:?}.log"));
            let mut j = Journal::create(&path, FaultPlan::kill_at(2, mode)).unwrap();
            j.append(RecordKind::Input, b"zero").unwrap();
            j.append(RecordKind::Input, b"one").unwrap();
            match j.append(RecordKind::Input, b"two") {
                Err(JournalError::Killed) => {}
                other => panic!("expected Killed, got {other:?}"),
            }
            drop(j);
            // recovery finds exactly the two durable records
            let (records, mut j2) = Journal::recover(&path, FaultPlan::none()).unwrap();
            assert_eq!(records.len(), 2, "tear mode {mode:?}");
            // and the journal is append-ready again
            j2.append(RecordKind::Input, b"two").unwrap();
            std::fs::remove_dir_all(dir).ok();
        }
    }
}
