//! `skrull serve` — a crash-safe daemon wrapping the fleet scheduler.
//!
//! The batch path (`skrull fleet`, [`crate::fleet::sim::simulate`]) plans
//! a whole workload in one call; this module runs the *same*
//! deterministic core ([`crate::fleet::FleetCore`]) as a long-lived
//! process fed by a JSONL control plane, and makes it durable:
//!
//! - [`control`] — the flat-JSON control records (config / submit /
//!   status / node-loss / drain / shutdown) and their renderers.
//! - [`journal`] — the write-ahead event journal: length-prefixed,
//!   FNV-1a-checksummed records; torn tails truncate, mid-file
//!   corruption is fatal.
//! - [`snapshot`] — atomic full-state snapshots that let the journal be
//!   truncated; restart = snapshot + journal-suffix replay.
//! - [`fault`] — seeded deterministic fault injection (kills with
//!   clean/torn/bit-flipped tails, transient write errors) at the
//!   journal I/O boundary, driving every recovery path in tests and CI.
//! - [`daemon`] — the loop tying them together, plus `--record`,
//!   `--replay` and `--smoke` entry points.
//!
//! Keystone invariant, enforced at recovery time and by the CI replay
//! gate: **the daemon must never out-decide the simulator.**  Replaying
//! a recorded log through the daemon and through `fleet::sim` yields
//! byte-identical `BENCH_fleet.json` cell payloads, and recovery proves
//! every journaled event against a freshly re-decided core.
//!
//! Determinism: no wall-clock reads anywhere in this tree (time is
//! simulation time from the control records; retry backoff is a virtual
//! tick counter), so `skrull lint`'s `wall-clock-in-pure-code` rule
//! holds over `serve/` and every run is replayable.

pub mod control;
pub mod daemon;
pub mod fault;
pub mod journal;
pub mod snapshot;

pub use control::{parse_line, ConfigSpec, ControlRecord};
pub use daemon::{
    record_log, replay_via_daemon, replay_via_sim, run, run_smoke, DaemonOptions, Outcome,
};
pub use fault::{FaultPlan, TearMode};
pub use journal::{Journal, JournalError, RecordKind};
pub use snapshot::Snapshot;
