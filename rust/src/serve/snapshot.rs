//! Periodic snapshots of the daemon's fleet state, so restart cost stays
//! bounded: recovery loads the snapshot, then replays only the journal
//! suffix written after it.  The codec is the checkpoint idiom once more
//! — magic, version, little-endian fields, trailing FNV-1a checksum —
//! and the file lands via `write_atomic` (write-tmp → fsync → rename →
//! fsync(dir)).
//!
//! Built schedules (`BuiltRun`) are deliberately *not* serialized: the
//! snapshot stores a per-job `was_built` flag, and restore marks those
//! jobs for a build-cache *refill* — the next `ensure_built` rebuilds
//! the schedule (bit-identical, it is a pure function of the job spec)
//! without recounting it, keeping the build-once gate honest across
//! restarts.

use std::io::Read;
use std::path::Path;

use crate::config::Policy;
use crate::coordinator::state::fnv1a;
use crate::fleet::job::FleetJob;
use crate::fleet::queue::QueueEntry;
use crate::fleet::sim::{FleetCore, Running};
use crate::util::error::{Context, Result};

const SNAP_MAGIC: [u8; 8] = *b"SKRLSNP\0";
const SNAP_VERSION: u32 = 1;

fn push_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn push_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn push_str(buf: &mut Vec<u8>, s: &str) {
    push_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn push_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    push_u64(buf, b.len() as u64);
    buf.extend_from_slice(b);
}

struct Rd<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn u64(&mut self) -> Result<u64> {
        let s = self
            .bytes
            .get(self.off..self.off + 8)
            .ok_or_else(|| crate::anyhow!("snapshot truncated at byte {}", self.off))?;
        self.off += 8;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize(&mut self) -> Result<usize> {
        let x = self.u64()?;
        crate::ensure!(x <= u32::MAX as u64, "snapshot count {x} implausibly large");
        Ok(x as usize)
    }

    fn byte(&mut self) -> Result<u8> {
        let b = *self
            .bytes
            .get(self.off)
            .ok_or_else(|| crate::anyhow!("snapshot truncated at byte {}", self.off))?;
        self.off += 1;
        Ok(b)
    }

    fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        let s = self
            .bytes
            .get(self.off..self.off + n)
            .ok_or_else(|| crate::anyhow!("snapshot truncated at byte {}", self.off))?;
        self.off += n;
        Ok(s)
    }

    fn str(&mut self) -> Result<String> {
        let b = self.blob()?;
        String::from_utf8(b.to_vec()).map_err(|_| crate::anyhow!("snapshot string not utf-8"))
    }
}

/// Everything a restart needs, decoded but not yet applied.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// The raw config line, so the daemon can rebuild the core skeleton
    /// before applying state (the snapshot is self-contained).
    pub config_line: String,
    /// Control-plane inputs already absorbed (journaled Input records
    /// before the snapshot); the daemon skips this many on restart.
    pub consumed_inputs: u64,
    bytes_after_header: SnapState,
}

#[derive(Clone, Debug)]
struct SnapState {
    jobs: Vec<FleetJob>,
    build_counts: Vec<usize>,
    was_built: Vec<bool>,
    queue: Vec<QueueEntry>,
    running: Vec<RunningState>,
    in_system: Vec<usize>,
    tenants: Vec<[f64; 6]>,
    queue_wait: Vec<f64>,
    scalars: [f64; 10],
    pool_nodes: Vec<usize>,
    pool_free: Vec<usize>,
}

#[derive(Clone, Debug)]
struct RunningState {
    job: usize,
    pool: usize,
    nodes: usize,
    gpus: usize,
    start: f64,
    done_before: usize,
    iter_ends: Vec<f64>,
    finish: f64,
    event_time: f64,
    preempt_at: Option<usize>,
    wait_so_far: f64,
    service_so_far: f64,
}

/// Serialize the core (plus its config line and input high-water mark).
pub fn encode(core: &FleetCore, config_line: &str, consumed_inputs: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(512);
    buf.extend_from_slice(&SNAP_MAGIC);
    buf.extend_from_slice(&SNAP_VERSION.to_le_bytes());
    push_str(&mut buf, config_line);
    push_u64(&mut buf, consumed_inputs);
    push_u64(&mut buf, core.jobs.len() as u64);
    for j in &core.jobs {
        push_u64(&mut buf, j.id);
        push_u64(&mut buf, j.tenant as u64);
        push_str(&mut buf, j.dataset);
        push_u64(&mut buf, j.dp as u64);
        push_u64(&mut buf, j.cp as u64);
        push_u64(&mut buf, j.batch_size as u64);
        push_u64(&mut buf, j.iterations as u64);
        push_u64(&mut buf, j.seq_count as u64);
        push_str(&mut buf, j.policy.name());
        push_u64(&mut buf, j.priority as u64);
        push_f64(&mut buf, j.submit_time);
        push_u64(&mut buf, j.seed);
    }
    for &c in &core.build_counts {
        push_u64(&mut buf, c as u64);
    }
    for b in &core.builts {
        buf.push(b.is_some() as u8);
    }
    push_u64(&mut buf, core.queue.len() as u64);
    for e in &core.queue {
        push_u64(&mut buf, e.job as u64);
        push_f64(&mut buf, e.enqueued_at);
        push_u64(&mut buf, e.done_iters as u64);
        match &e.resume {
            Some(bytes) => {
                buf.push(1);
                push_bytes(&mut buf, bytes);
            }
            None => buf.push(0),
        }
        push_f64(&mut buf, e.wait_so_far);
        push_f64(&mut buf, e.service_so_far);
    }
    push_u64(&mut buf, core.running.len() as u64);
    for r in &core.running {
        push_u64(&mut buf, r.job as u64);
        push_u64(&mut buf, r.pool as u64);
        push_u64(&mut buf, r.nodes as u64);
        push_u64(&mut buf, r.gpus as u64);
        push_f64(&mut buf, r.start);
        push_u64(&mut buf, r.done_before as u64);
        push_u64(&mut buf, r.iter_ends.len() as u64);
        for &t in &r.iter_ends {
            push_f64(&mut buf, t);
        }
        push_f64(&mut buf, r.finish);
        push_f64(&mut buf, r.event_time);
        match r.preempt_at {
            Some(i) => {
                buf.push(1);
                push_u64(&mut buf, i as u64);
            }
            None => buf.push(0),
        }
        push_f64(&mut buf, r.wait_so_far);
        push_f64(&mut buf, r.service_so_far);
    }
    for &n in &core.in_system {
        push_u64(&mut buf, n as u64);
    }
    for t in &core.tenants {
        push_u64(&mut buf, t.submitted as u64);
        push_u64(&mut buf, t.admitted as u64);
        push_u64(&mut buf, t.rejected as u64);
        push_u64(&mut buf, t.finished as u64);
        push_f64(&mut buf, t.service_seconds);
        push_u64(&mut buf, t.peak_in_flight as u64);
    }
    push_u64(&mut buf, core.queue_wait.len() as u64);
    for &w in core.queue_wait.samples() {
        push_f64(&mut buf, w);
    }
    push_f64(&mut buf, core.busy_gpu_seconds);
    push_u64(&mut buf, core.pricings as u64);
    push_u64(&mut buf, core.preemptions as u64);
    push_u64(&mut buf, core.priority_inversions as u64);
    push_u64(&mut buf, core.finished as u64);
    push_u64(&mut buf, core.admitted as u64);
    push_u64(&mut buf, core.rejected as u64);
    push_u64(&mut buf, core.evicted as u64);
    push_f64(&mut buf, core.last_finish);
    push_f64(&mut buf, core.now);
    for p in &core.engine.pools {
        push_u64(&mut buf, p.nodes as u64);
    }
    for &f in core.engine.free_state() {
        push_u64(&mut buf, f as u64);
    }
    let crc = fnv1a(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode and integrity-check a snapshot.  The pool count is taken from
/// the config line's pool set at `apply` time; decode stores raw vectors.
pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
    crate::ensure!(bytes.len() >= 8 + 4 + 8, "snapshot smaller than its framing");
    crate::ensure!(bytes[..8] == SNAP_MAGIC, "snapshot has wrong magic");
    let body = &bytes[..bytes.len() - 8];
    let mut crc = [0u8; 8];
    crc.copy_from_slice(&bytes[bytes.len() - 8..]);
    crate::ensure!(fnv1a(body) == u64::from_le_bytes(crc), "snapshot checksum mismatch");
    let mut rd = Rd { bytes: body, off: 8 };
    let version = {
        let s = &body[8..12];
        rd.off = 12;
        u32::from_le_bytes([s[0], s[1], s[2], s[3]])
    };
    crate::ensure!(version == SNAP_VERSION, "unsupported snapshot version {version}");
    let config_line = rd.str()?;
    let consumed_inputs = rd.u64()?;
    let n_jobs = rd.usize()?;
    let mut jobs = Vec::with_capacity(n_jobs);
    for _ in 0..n_jobs {
        let id = rd.u64()?;
        let tenant = rd.usize()?;
        let dataset = crate::serve::control::static_dataset(&rd.str()?)?;
        let dp = rd.usize()?;
        let cp = rd.usize()?;
        let batch_size = rd.usize()?;
        let iterations = rd.usize()?;
        let seq_count = rd.usize()?;
        let policy_name = rd.str()?;
        let policy = Policy::by_name(&policy_name)
            .ok_or_else(|| crate::anyhow!("snapshot names unknown policy {policy_name:?}"))?;
        let priority = rd.u64()? as u32;
        let submit_time = rd.f64()?;
        let seed = rd.u64()?;
        jobs.push(FleetJob {
            id,
            tenant,
            dataset,
            dp,
            cp,
            batch_size,
            iterations,
            seq_count,
            policy,
            priority,
            submit_time,
            seed,
        });
    }
    let mut build_counts = Vec::with_capacity(n_jobs);
    for _ in 0..n_jobs {
        build_counts.push(rd.usize()?);
    }
    let mut was_built = Vec::with_capacity(n_jobs);
    for _ in 0..n_jobs {
        was_built.push(rd.byte()? != 0);
    }
    let n_queue = rd.usize()?;
    let mut queue = Vec::with_capacity(n_queue);
    for _ in 0..n_queue {
        let job = rd.usize()?;
        let enqueued_at = rd.f64()?;
        let done_iters = rd.usize()?;
        let resume = if rd.byte()? != 0 { Some(rd.blob()?.to_vec()) } else { None };
        let wait_so_far = rd.f64()?;
        let service_so_far = rd.f64()?;
        crate::ensure!(job < n_jobs, "snapshot queue entry names job {job} of {n_jobs}");
        queue.push(QueueEntry { job, enqueued_at, done_iters, resume, wait_so_far, service_so_far });
    }
    let n_running = rd.usize()?;
    let mut running = Vec::with_capacity(n_running);
    for _ in 0..n_running {
        let job = rd.usize()?;
        let pool = rd.usize()?;
        let nodes = rd.usize()?;
        let gpus = rd.usize()?;
        let start = rd.f64()?;
        let done_before = rd.usize()?;
        let n_iters = rd.usize()?;
        let mut iter_ends = Vec::with_capacity(n_iters);
        for _ in 0..n_iters {
            iter_ends.push(rd.f64()?);
        }
        let finish = rd.f64()?;
        let event_time = rd.f64()?;
        let preempt_at = if rd.byte()? != 0 { Some(rd.usize()?) } else { None };
        let wait_so_far = rd.f64()?;
        let service_so_far = rd.f64()?;
        crate::ensure!(job < n_jobs, "snapshot running entry names job {job} of {n_jobs}");
        running.push(RunningState {
            job,
            pool,
            nodes,
            gpus,
            start,
            done_before,
            iter_ends,
            finish,
            event_time,
            preempt_at,
            wait_so_far,
            service_so_far,
        });
    }
    // tenant-indexed vectors: counts come from the config line at apply
    // time, so the snapshot stores its own lengths implicitly via the
    // config — parse them from what remains using the config's tenant
    // count, which apply() cross-checks.  Here, infer from the config
    // line itself to keep decode self-contained.
    let cfg = crate::serve::control::parse_line(&config_line)?;
    let n_tenants = match &cfg {
        crate::serve::control::ControlRecord::Config(c) => c.tenant_quotas.len(),
        _ => crate::bail!("snapshot config line is not a config record"),
    };
    let mut in_system = Vec::with_capacity(n_tenants);
    for _ in 0..n_tenants {
        in_system.push(rd.usize()?);
    }
    let mut tenants = Vec::with_capacity(n_tenants);
    for _ in 0..n_tenants {
        let submitted = rd.u64()? as f64;
        let admitted = rd.u64()? as f64;
        let rejected = rd.u64()? as f64;
        let finished = rd.u64()? as f64;
        let service = rd.f64()?;
        let peak = rd.u64()? as f64;
        tenants.push([submitted, admitted, rejected, finished, service, peak]);
    }
    let n_waits = rd.usize()?;
    let mut queue_wait = Vec::with_capacity(n_waits);
    for _ in 0..n_waits {
        queue_wait.push(rd.f64()?);
    }
    let busy = rd.f64()?;
    let pricings = rd.u64()? as f64;
    let preemptions = rd.u64()? as f64;
    let inversions = rd.u64()? as f64;
    let finished = rd.u64()? as f64;
    let admitted = rd.u64()? as f64;
    let rejected = rd.u64()? as f64;
    let evicted = rd.u64()? as f64;
    let last_finish = rd.f64()?;
    let now = rd.f64()?;
    let scalars = [
        busy,
        pricings,
        preemptions,
        inversions,
        finished,
        admitted,
        rejected,
        evicted,
        last_finish,
        now,
    ];
    let n_pools = match &cfg {
        crate::serve::control::ControlRecord::Config(c) => {
            crate::fleet::placement::ClusterSpec::by_name(&c.pool_set)
                .ok_or_else(|| crate::anyhow!("snapshot names unknown pool set {:?}", c.pool_set))?
                .pools
                .len()
        }
        _ => 0,
    };
    let mut pool_nodes = Vec::with_capacity(n_pools);
    for _ in 0..n_pools {
        pool_nodes.push(rd.usize()?);
    }
    let mut pool_free = Vec::with_capacity(n_pools);
    for _ in 0..n_pools {
        pool_free.push(rd.usize()?);
    }
    crate::ensure!(rd.off == body.len(), "snapshot has {} trailing bytes", body.len() - rd.off);
    Ok(Snapshot {
        config_line,
        consumed_inputs,
        bytes_after_header: SnapState {
            jobs,
            build_counts,
            was_built,
            queue,
            running,
            in_system,
            tenants,
            queue_wait,
            scalars,
            pool_nodes,
            pool_free,
        },
    })
}

impl Snapshot {
    /// Apply the decoded state onto a freshly constructed core (built
    /// from this snapshot's config line).  Jobs that had cached builds
    /// are marked for refill — see the module docs.
    pub fn apply(&self, core: &mut FleetCore) -> Result<()> {
        let s = &self.bytes_after_header;
        crate::ensure!(
            core.tenant_specs.len() == s.in_system.len(),
            "snapshot tenant count {} != core {}",
            s.in_system.len(),
            core.tenant_specs.len()
        );
        let n = s.jobs.len();
        crate::ensure!(
            s.build_counts.len() == n && s.was_built.len() == n,
            "snapshot per-job vectors disagree"
        );
        core.jobs = s.jobs.clone();
        core.builts = s.jobs.iter().map(|_| None).collect();
        core.build_counts = s.build_counts.clone();
        core.refill = s.was_built.clone();
        core.queue = s.queue.clone();
        core.running = s
            .running
            .iter()
            .map(|r| Running {
                job: r.job,
                pool: r.pool,
                nodes: r.nodes,
                gpus: r.gpus,
                start: r.start,
                done_before: r.done_before,
                iter_ends: r.iter_ends.clone(),
                finish: r.finish,
                event_time: r.event_time,
                preempt_at: r.preempt_at,
                wait_so_far: r.wait_so_far,
                service_so_far: r.service_so_far,
            })
            .collect();
        core.in_system = s.in_system.clone();
        core.tenants = s
            .tenants
            .iter()
            .map(|t| crate::fleet::sim::TenantStats {
                submitted: t[0] as usize,
                admitted: t[1] as usize,
                rejected: t[2] as usize,
                finished: t[3] as usize,
                service_seconds: t[4],
                peak_in_flight: t[5] as usize,
            })
            .collect();
        core.queue_wait = crate::util::stats::Summary::from_samples(s.queue_wait.clone());
        core.busy_gpu_seconds = s.scalars[0];
        core.pricings = s.scalars[1] as usize;
        core.preemptions = s.scalars[2] as usize;
        core.priority_inversions = s.scalars[3] as usize;
        core.finished = s.scalars[4] as usize;
        core.admitted = s.scalars[5] as usize;
        core.rejected = s.scalars[6] as usize;
        core.evicted = s.scalars[7] as usize;
        core.last_finish = s.scalars[8];
        core.now = s.scalars[9];
        core.engine
            .restore_state(&s.pool_nodes, &s.pool_free)
            .context("snapshot pool state rejected")?;
        Ok(())
    }
}

/// Write a snapshot durably (write-tmp → fsync → rename → fsync(dir)).
pub fn save(path: &Path, core: &FleetCore, config_line: &str, consumed: u64) -> Result<()> {
    let bytes = encode(core, config_line, consumed);
    crate::util::fsio::write_atomic(path, &bytes, "snap.tmp")
        .with_context(|| format!("writing snapshot {}", path.display()))?;
    Ok(())
}

/// Load a snapshot if one exists; `Ok(None)` when the file is absent.
pub fn load(path: &Path) -> Result<Option<Snapshot>> {
    let mut bytes = Vec::new();
    match std::fs::File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)
                .with_context(|| format!("reading snapshot {}", path.display()))?;
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(crate::anyhow!("opening snapshot {}: {e}", path.display()));
        }
    }
    Ok(Some(decode(&bytes)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::job::{synthesize, ArrivalPattern};
    use crate::fleet::placement::ClusterSpec;
    use crate::fleet::queue::FleetPolicy;
    use crate::fleet::sim::SimOptions;
    use crate::serve::control::{render_config, ConfigSpec};

    fn mid_flight_core() -> (FleetCore, String) {
        // drive a bursty fleet partway so the snapshot has queued,
        // running and finished jobs all at once
        let workload = synthesize(ArrivalPattern::Bursty, 12, 11);
        let spec = ConfigSpec {
            arrival: "bursty".to_string(),
            fleet_policy: FleetPolicy::Priority,
            pool_set: "paper".to_string(),
            serial_scheduler: false,
            tenant_weights: workload.tenants.iter().map(|t| t.weight).collect(),
            tenant_quotas: workload.tenants.iter().map(|t| t.quota).collect(),
        };
        let opts = SimOptions {
            policy: spec.fleet_policy,
            cluster: ClusterSpec::by_name(&spec.pool_set).unwrap(),
            serial_scheduler: spec.serial_scheduler,
        };
        let mut core = FleetCore::new(workload.tenants.clone(), opts);
        for job in &workload.jobs {
            core.step_until(job.submit_time).unwrap();
            core.submit(job.clone(), job.submit_time).unwrap();
        }
        (core, render_config(&spec))
    }

    #[test]
    fn snapshot_restores_to_a_bit_identical_report() {
        let (mut core, config_line) = mid_flight_core();
        let bytes = encode(&core, &config_line, 13);
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.consumed_inputs, 13);
        assert_eq!(snap.config_line, config_line);
        // rebuild a fresh core from the config and apply the snapshot
        let spec = match crate::serve::control::parse_line(&config_line).unwrap() {
            crate::serve::control::ControlRecord::Config(c) => c,
            other => panic!("expected config, got {other:?}"),
        };
        let tenants: Vec<crate::fleet::job::Tenant> = spec
            .tenant_weights
            .iter()
            .zip(&spec.tenant_quotas)
            .enumerate()
            .map(|(id, (&weight, &quota))| crate::fleet::job::Tenant { id, weight, quota })
            .collect();
        let opts = SimOptions {
            policy: spec.fleet_policy,
            cluster: ClusterSpec::by_name(&spec.pool_set).unwrap(),
            serial_scheduler: spec.serial_scheduler,
        };
        let mut restored = FleetCore::new(tenants, opts);
        snap.apply(&mut restored).unwrap();
        // both cores drain to byte-identical reports — the keystone of
        // snapshot + suffix-replay recovery
        core.drain().unwrap();
        restored.drain().unwrap();
        let a = core.finish_report().unwrap();
        let b = restored.finish_report().unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.fairness_ratio.to_bits(), b.fairness_ratio.to_bits());
        assert_eq!(a.finished, b.finished);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.builds, b.builds, "refill must not recount builds");
        assert_eq!(a.pricings, b.pricings);
    }

    #[test]
    fn snapshot_codec_survives_exhaustive_mutation() {
        let (core, config_line) = mid_flight_core();
        let bytes = encode(&core, &config_line, 2);
        // bit flips, truncations, trailing garbage, random buffers: all
        // structured errors (the trailing crc covers every byte)
        crate::util::proptest::assert_codec_rejects_mutants(&bytes[..], 32, 23, |b| decode(b));
    }

    #[test]
    fn save_load_round_trips_through_the_filesystem() {
        let dir = std::env::temp_dir().join(format!("skrull_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.snap");
        let (core, config_line) = mid_flight_core();
        save(&path, &core, &config_line, 5).unwrap();
        assert!(!path.with_extension("snap.tmp").exists(), "tmp must be renamed away");
        let snap = load(&path).unwrap().unwrap();
        assert_eq!(snap.consumed_inputs, 5);
        // an absent snapshot is None, not an error
        assert!(load(&dir.join("absent.snap")).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
