//! skrull — the launcher.
//!
//! Subcommands:
//!   schedule  — schedule one sampled global batch, print the plan + times
//!   simulate  — run N simulated iterations under each policy, report speedup
//!   e2e       — the end-to-end sweep: policies × distributions × topologies
//!               through the run engine; writes BENCH_e2e.json
//!   fleet     — multi-tenant fleet-scheduling sweep: arrival patterns ×
//!               queue policies × pool sets; writes BENCH_fleet.json
//!   serve     — crash-safe fleet daemon over a JSONL control plane, with
//!               a checksummed write-ahead journal, snapshots, seeded
//!               fault injection and byte-identical recovery replay
//!   lint      — repo-aware static analysis of rust/src; writes
//!               LINT_REPORT.json (the CI gate behind --validate)
//!   sched-bench — scheduler overhead + K-scaling benches; writes
//!               BENCH_sched_overhead.json
//!   calibrate — trace → fitted coefficients: emit a calibration trace
//!               (--emit), fit one (--trace), write the profile (--out),
//!               gate it (--validate)
//!   train     — end-to-end tiny-model training through PJRT artifacts
//!   analyze   — dataset length-distribution report (Fig. 1a / Table 1)
//!   profile   — print the offline-profiling fits (Appendix A)
//!
//! Configuration comes from `--config <file>` (TOML subset) or direct flags
//! (--model, --dataset, --dp, --cp, --batch-size, --policy, --bucket-size,
//! --iterations, --seed, --sync, --cost-profile).

use skrull::bail;
use skrull::util::error::{Context, Result};

use skrull::bench::e2e::{self, E2eOptions};
use skrull::bench::TableBuilder;
use skrull::cli::Args;
use skrull::cluster::run::{build_run_streamed, price_run, simulate_run, RunConfig};
use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::coordinator::corpus::CorpusConfig;
use skrull::coordinator::{Trainer, TrainerOptions};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::profile;
use skrull::rng::Rng;
use skrull::stream::{ingest_dataset, StreamSource};
use skrull::util::stats::fraction_below;
use skrull::util::{fmt_secs, fmt_tokens};

fn memory_from_args(args: &Args, mem: &mut skrull::memplan::MemoryConfig) -> Result<()> {
    if let Some(c) = args.get("capacity") {
        mem.source = skrull::memplan::CapacitySource::by_name(c)
            .context("unknown --capacity (fixed | hbm-derived)")?;
    }
    // --hbm-gb accepts a scalar or a per-node list ("80,40,80,80"); the
    // minimum-HBM node governs derived capacities and the OOM line
    if args.get("hbm-gb").is_some() {
        let nodes: Vec<f64> = args.list_or("hbm-gb", &[])?;
        skrull::ensure!(
            nodes.iter().all(|&g| g.is_finite() && g > 0.0),
            "--hbm-gb entries must be positive"
        );
        match nodes.as_slice() {
            [] => skrull::bail!("--hbm-gb needs at least one value"),
            [one] => {
                mem.hbm_gb = *one;
                mem.hbm_gb_nodes = None;
            }
            many => {
                // `effective_hbm_gb()` folds the list; the scalar keeps
                // its default and is never read when a list is set
                mem.hbm_gb_nodes = Some(many.to_vec());
            }
        }
    }
    if let Some(r) = args.get("recompute") {
        mem.recompute = skrull::memplan::RecomputePolicy::by_name(r)
            .context("unknown --recompute (full | selective | none)")?;
    }
    Ok(())
}

fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::load(path)?
    } else {
        let model = ModelSpec::by_name(args.str_or("model", "qwen2.5-0.5b"))
            .context("unknown --model (qwen2.5-0.5b | qwen2.5-7b | tiny)")?;
        ExperimentConfig::paper_default(model, args.str_or("dataset", "wikipedia"))
    };
    cfg.cluster.dp = args.parse_or("dp", cfg.cluster.dp)?;
    cfg.cluster.cp = args.parse_or("cp", cfg.cluster.cp)?;
    cfg.cluster.batch_size = args.parse_or("batch-size", cfg.cluster.batch_size)?;
    cfg.bucket_size = args.parse_or("bucket-size", cfg.bucket_size)?;
    cfg.iterations = args.parse_or("iterations", cfg.iterations)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    if args.flag("sync") {
        cfg.pipelined = false;
    }
    if args.flag("epoch") {
        cfg.epoch = true;
    }
    // scheduler scaling knobs: --shards 0 = auto (one per core), same
    // convention as the TOML key; --incremental turns on reuse of the
    // previous iteration's solution (byte-identical either way)
    match args.parse_or("shards", cfg.shards)? {
        0 => cfg.shards = skrull::util::par::max_threads().max(1),
        n => cfg.shards = n,
    }
    if args.flag("incremental") {
        cfg.incremental = true;
    }
    // streaming data plane: --spill-dir turns the out-of-core path on,
    // --stream-ram-mb bounds the page-cache budget.  Schedules are
    // byte-identical either way, so these are safe to flip per run.
    if let Some(dir) = args.get("spill-dir") {
        cfg.stream.spill_dir = Some(dir.to_string());
    }
    cfg.stream.ram_mb = args.parse_or("stream-ram-mb", cfg.stream.ram_mb)?;
    skrull::ensure!(cfg.stream.ram_mb > 0, "--stream-ram-mb must be positive");
    if let Some(p) = args.get("policy") {
        cfg.policy = Policy::by_name(p).context("unknown --policy")?;
    }
    if let Some(p) = args.get("cost-profile") {
        cfg.cost = skrull::config::CostSource::calibrated(p)?;
        cfg.cost.ensure_model(cfg.model.name)?;
    }
    memory_from_args(args, &mut cfg.memory)?;
    // same node-count check the TOML path enforces: a per-node HBM list
    // must name every node of the cluster layout
    if let Some(nodes) = &cfg.memory.hbm_gb_nodes {
        skrull::ensure!(
            nodes.len() == cfg.cluster.nodes,
            "--hbm-gb lists {} nodes but the cluster has {}",
            nodes.len(),
            cfg.cluster.nodes
        );
    }
    // resolve the capacity authority once, up front: with --capacity
    // hbm-derived every downstream consumer (dataset truncation, loader,
    // run engine) sees the memplan-derived C
    let cfg = cfg
        .resolve_capacity()
        .context("deriving bucket capacity from the HBM budget")?;
    Ok(cfg)
}

fn dataset_for(cfg: &ExperimentConfig, n: usize) -> Result<Dataset> {
    let dist = LengthDistribution::by_name(&cfg.dataset)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let ds = Dataset::synthesize(&dist, n, cfg.seed ^ 0xD5);
    // truncate to what the parallel config can hold (as real SFT does)
    let cap = cfg.bucket_size * cfg.cluster.cp as u32;
    Ok(ds.truncated(cap))
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let ds = dataset_for(&cfg, 100_000)?;
    let cost = cfg.cost_model();
    let mut loader = ScheduledLoader::new(&ds, &cfg);
    let (batch, sched) = loader.next_iteration()?;
    let sim = simulate_iteration(&sched, &cost, cfg.cluster.cp);

    println!(
        "scheduled {} sequences ({} tokens) under {:?}",
        batch.len(),
        fmt_tokens(batch.iter().map(|s| s.len as u64).sum()),
        cfg.policy
    );
    for (i, rank) in sched.ranks.iter().enumerate() {
        let mbs = &rank.micro_batches;
        let toks: u64 = mbs.iter().map(|m| m.total_tokens()).sum();
        let dist: usize = mbs.iter().map(|m| m.plan.num_distributed()).sum();
        println!(
            "  dp{i}: {} micro-batches, {} tokens, {dist} sharded seqs, span {}",
            mbs.len(),
            fmt_tokens(toks),
            fmt_secs(sim.rank_spans[i]),
        );
    }
    println!(
        "iteration time {} (grad sync {}), utilization {:.1}%, dp imbalance {:.3}, sched overhead {}",
        fmt_secs(sim.total_time),
        fmt_secs(sim.grad_sync),
        100.0 * sim.compute_utilization,
        sim.dp_imbalance,
        fmt_secs(loader.mean_sched_seconds()),
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let ds = dataset_for(&cfg, 100_000)?;
    let cost = cfg.cost_model();
    let run = if cfg.epoch {
        RunConfig::epoch(cfg.pipelined)
    } else {
        RunConfig::new(cfg.iterations, cfg.pipelined)
    };

    // streaming data plane: with --spill-dir the dataset is spilled once
    // and every policy's run streams batches through the bounded page
    // cache; schedules (and so every printed number) are byte-identical
    // to the in-memory path — the trailing telemetry line is the only
    // visible difference
    let stream_ingest = if cfg.stream.enabled() {
        let dir = cfg.stream.spill_dir.clone().unwrap_or_default();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating spill dir {dir}"))?;
        let path = std::path::PathBuf::from(&dir).join("simulate.spill");
        let report = ingest_dataset(&ds, &path, &cfg.stream, cfg.seed)
            .map_err(|e| skrull::anyhow!("spilling {}: {e}", path.display()))?;
        Some((path, report))
    } else {
        None
    };

    let policies = [Policy::Baseline, Policy::DacpOnly, Policy::Skrull];
    let mut base_wall = None;
    let mut peak_stream_rss = 0u64;
    println!(
        "model={} dataset={} <DP={},CP={},B={}> C={} ({}) {} loader={}",
        cfg.model.name,
        ds.name,
        cfg.cluster.dp,
        cfg.cluster.cp,
        cfg.cluster.batch_size,
        fmt_tokens(cfg.bucket_size as u64),
        cfg.memory.source.name(),
        if cfg.epoch { "one epoch".to_string() } else { format!("iters={}", cfg.iterations) },
        run.mode.name(),
    );
    for policy in policies {
        let mut pcfg = cfg.clone();
        pcfg.policy = policy;
        let report = match &stream_ingest {
            Some((path, ingest)) => {
                let mut src = StreamSource::open(path, &cfg.stream)
                    .map_err(|e| skrull::anyhow!("opening spill {}: {e}", path.display()))?;
                let built = build_run_streamed(&mut src, ingest, &pcfg, &run)?;
                price_run(&built, &cost, &built.topology)
            }
            None => simulate_run(&ds, &pcfg, &cost, &run)?,
        };
        peak_stream_rss = peak_stream_rss.max(report.peak_stream_rss_bytes);
        let wall = report.wall_seconds();
        let iters = report.iterations.len().max(1);
        let base = *base_wall.get_or_insert(wall);
        println!(
            "  {:<10} mean iter {}  speedup {:.2}x  utilization {:.1}%  peak mem {:.1}%  exposed sched {}",
            policy.name(),
            fmt_secs(wall / iters as f64),
            base / wall,
            100.0 * report.utilization(),
            100.0 * report.peak_mem_fraction(),
            fmt_secs(report.exposed_sched_seconds),
        );
    }
    if let Some((_, ingest)) = &stream_ingest {
        println!(
            "  streamed: {} drift event(s), {} recalibration(s), peak stream RSS {:.2} MiB (budget {} MiB)",
            ingest.drift_events.len(),
            ingest.recalibrations.len(),
            peak_stream_rss as f64 / (1024.0 * 1024.0),
            cfg.stream.ram_mb,
        );
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    // validation-only mode (the CI gate): `--validate=FILE`, or bare
    // `--validate` with the file as a positional argument
    let validate_path = args.get("validate").map(str::to_string).or_else(|| {
        if args.flag("validate") {
            args.positional.get(1).cloned()
        } else {
            None
        }
    });
    if args.flag("validate") && validate_path.is_none() {
        skrull::bail!("e2e --validate needs a file: `e2e --validate=BENCH_e2e.json`");
    }
    if let Some(path) = validate_path {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path}"))?;
        e2e::validate_json(&text).with_context(|| format!("{path} failed validation"))?;
        println!("{path}: ok");
        return Ok(());
    }

    let mut opts = if args.flag("smoke") {
        E2eOptions::smoke()
    } else {
        E2eOptions::paper_default()
    };
    if let Some(m) = args.get("model") {
        opts.model = ModelSpec::by_name(m).context("unknown --model")?;
    }
    if let Some(d) = args.get("datasets") {
        opts.datasets = d.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(t) = args.get("topologies") {
        // "4x8,2x16" → [(4,8), (2,16)]
        opts.topologies = t
            .split(',')
            .map(|pair| {
                let (dp, cp) = pair
                    .trim()
                    .split_once('x')
                    .with_context(|| format!("bad topology {pair:?}, want DPxCP"))?;
                Ok((
                    dp.parse().map_err(|_| skrull::anyhow!("bad dp in {pair:?}"))?,
                    cp.parse().map_err(|_| skrull::anyhow!("bad cp in {pair:?}"))?,
                ))
            })
            .collect::<Result<Vec<(usize, usize)>>>()?;
    }
    opts.iterations = args.parse_or("iterations", opts.iterations)?;
    opts.dataset_samples = args.parse_or("samples", opts.dataset_samples)?;
    if args.get("seeds").is_some() {
        opts.seeds = args.list_or("seeds", &[])?;
        skrull::ensure!(!opts.seeds.is_empty(), "--seeds needs at least one seed");
    } else if let Some(s) = args.get("seed") {
        opts.seeds = vec![s.parse().map_err(|_| skrull::anyhow!("bad --seed {s:?}"))?];
    }
    if let Some(b) = args.get("batch-size") {
        opts.batch_size =
            Some(b.parse().map_err(|_| skrull::anyhow!("bad --batch-size {b:?}"))?);
    }
    if args.flag("sync") {
        opts.pipelined = false;
    }
    if args.flag("epoch") {
        opts.epoch = true;
    }
    // worker count: `[run] jobs` from --config seeds the default, the
    // --jobs flag wins; 0 means "auto" (available parallelism).  The e2e
    // grid is fixed by its own flags, so jobs is the only config key this
    // subcommand reads — any other key in the file is rejected rather
    // than silently ignored.
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let table =
            skrull::config::toml::parse(&text).map_err(|e| skrull::anyhow!("{path}: {e}"))?;
        for key in table.entries.keys() {
            skrull::ensure!(
                key == "run.jobs",
                "e2e --config reads only the `[run] jobs` key, but {path} sets {key:?}; \
                 pass the rest as e2e flags (see usage)"
            );
        }
        // one parser for the key's semantics (0/negative = auto)
        opts.jobs = ExperimentConfig::from_table(&table)?.jobs;
    }
    opts.jobs = match args.parse_or("jobs", opts.jobs)? {
        0 => E2eOptions::paper_default().jobs,
        n => n,
    };
    if args.flag("deterministic-timing") {
        opts.deterministic_timing = true;
    }
    // streaming data plane: with --spill-dir every cell's dataset is
    // spilled to disk and the run engine streams batches through the
    // bounded page cache; digests prove byte-identity to the in-memory
    // path, so the flag changes RSS and drift telemetry only
    if let Some(dir) = args.get("spill-dir") {
        opts.stream.spill_dir = Some(dir.to_string());
    }
    opts.stream.ram_mb = args.parse_or("stream-ram-mb", opts.stream.ram_mb)?;
    skrull::ensure!(opts.stream.ram_mb > 0, "--stream-ram-mb must be positive");
    if let Some(p) = args.get("cost-profile") {
        opts.cost = skrull::config::CostSource::calibrated(p)?;
        opts.cost.ensure_model(opts.model.name)?;
    }
    memory_from_args(args, &mut opts.memory)?;
    // every sweep cell runs on the paper-default cluster layout; read the
    // node count from the same config source run_sweep uses
    if let Some(nodes) = &opts.memory.hbm_gb_nodes {
        let testbed_nodes =
            ExperimentConfig::paper_default(opts.model.clone(), "wikipedia").cluster.nodes;
        skrull::ensure!(
            nodes.len() == testbed_nodes,
            "--hbm-gb lists {} nodes but the e2e testbed has {testbed_nodes}",
            nodes.len()
        );
    }

    let iters_desc = if opts.epoch {
        "one epoch".to_string()
    } else {
        format!("{} iterations", opts.iterations)
    };
    println!(
        "e2e sweep: {} policies × {} datasets × {} topologies × {} seeds, {}, {} loader, capacity {}, cost {}, {} job{}",
        e2e::ALL_POLICIES.len(),
        opts.datasets.len(),
        opts.topologies.len(),
        opts.seeds.len(),
        iters_desc,
        if opts.pipelined { "pipelined" } else { "synchronous" },
        opts.memory.source.name(),
        opts.cost.name(),
        opts.jobs,
        if opts.jobs == 1 { "" } else { "s" },
    );
    let sweep = e2e::run_sweep(&opts)?;
    println!(
        "sweep finished in {} ({} cells, one scheduling pass per cell)",
        fmt_secs(sweep.sweep_seconds),
        sweep.cells.len(),
    );

    let mut table = TableBuilder::new("End-to-end simulated runs").header(&[
        "topology",
        "dataset",
        "policy",
        "total",
        "speedup",
        "±std",
        "util",
        "sched exposed",
        "padding",
        "peak mem",
        "oom",
    ]);
    for c in &sweep.cells {
        table.row(&[
            format!("<DP={},CP={}>", c.dp, c.cp),
            c.dataset.clone(),
            c.policy.name().to_string(),
            fmt_secs(c.report.wall_seconds()),
            format!("{:.2}x", c.speedup_vs_baseline),
            format!("{:.3}", c.speedup_std),
            format!("{:.1}%", 100.0 * c.report.utilization()),
            format!("{:.4}%", 100.0 * c.report.sched_overhead_fraction()),
            format!("{:.1}%", 100.0 * c.report.padding_fraction()),
            format!("{:.1}%", 100.0 * c.report.peak_mem_fraction()),
            c.report.oom_count().to_string(),
        ]);
    }
    table.print();

    let out_path = args.str_or("out", "BENCH_e2e.json");
    let json = e2e::render_json(&sweep);
    e2e::validate_json(&json).context("self-check of rendered BENCH_e2e.json")?;
    std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    // per-cell schedule digests, for the spilled-vs-in-memory CI cmp: the
    // full JSONs legitimately differ in drift/RSS telemetry, the digests
    // must not
    if let Some(path) = args.get("sched-digest") {
        std::fs::write(path, e2e::render_digests(&sweep))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use skrull::analysis;

    // `--validate=FILE` checks an existing report (parse + consistency +
    // zero unsuppressed findings) without rescanning, same convention as
    // `e2e --validate`.  Bare `--validate` is the CI gate: scan, write
    // the report, and fail on any unsuppressed finding.
    if let Some(path) = args.get("validate") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        analysis::validate_json(&text).with_context(|| format!("{path} failed validation"))?;
        println!("{path}: ok");
        return Ok(());
    }

    let root = args.str_or("root", "rust/src");
    let outcome = analysis::lint_tree(std::path::Path::new(&root))
        .with_context(|| format!("linting {root}"))?;
    print!("{}", analysis::render_human(&outcome));

    let out_path = args.str_or("out", "LINT_REPORT.json");
    let json = analysis::render_json(&outcome);
    analysis::parse_report(&json).context("self-check of rendered LINT_REPORT.json")?;
    std::fs::write(&out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");

    if args.flag("validate") {
        let n = outcome.unsuppressed();
        skrull::ensure!(
            n == 0,
            "{root}: {n} unsuppressed lint finding(s) — fix them or add a justified \
             `// skrull-lint: allow(<rule>) -- <reason>`"
        );
        println!("{root}: lint clean ({} suppressed, all justified)", outcome.suppressed());
    }
    Ok(())
}

fn cmd_sched_bench(args: &Args) -> Result<()> {
    use skrull::bench::sched_overhead as sb;

    // validation-only mode (the CI gate), same calling convention as
    // `e2e --validate`
    let validate_path = args.get("validate").map(str::to_string).or_else(|| {
        if args.flag("validate") {
            args.positional.get(1).cloned()
        } else {
            None
        }
    });
    if args.flag("validate") && validate_path.is_none() {
        skrull::bail!(
            "sched-bench --validate needs a file: `sched-bench --validate=BENCH_sched_overhead.json`"
        );
    }
    if let Some(path) = validate_path {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        sb::validate_json(&text).with_context(|| format!("{path} failed validation"))?;
        println!("{path}: ok");
        return Ok(());
    }

    let mut opts = if args.flag("smoke") {
        sb::SchedBenchOptions::smoke()
    } else {
        sb::SchedBenchOptions::paper_default()
    };
    if let Some(m) = args.get("model") {
        opts.model = ModelSpec::by_name(m).context("unknown --model")?;
    }
    if let Some(d) = args.get("dataset") {
        opts.dataset = d.to_string();
    }
    opts.shards = args.parse_or("shards", opts.shards)?;
    println!(
        "sched-bench: overhead at K={:?}, scaling at K={:?}, {} shard(s)",
        opts.overhead_ks,
        opts.scaling_ks,
        if opts.shards == 0 { "auto".to_string() } else { opts.shards.to_string() },
    );
    let report = sb::run(&opts)?;
    sb::print_report(&report);

    let out_path = args.str_or("out", "BENCH_sched_overhead.json");
    let json = sb::render_json(&report);
    sb::validate_json(&json).context("self-check of rendered BENCH_sched_overhead.json")?;
    std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use skrull::bench::fleet as fb;

    // validation-only mode (the CI gate), same calling convention as
    // `e2e --validate`
    let validate_path = args.get("validate").map(str::to_string).or_else(|| {
        if args.flag("validate") {
            args.positional.get(1).cloned()
        } else {
            None
        }
    });
    if args.flag("validate") && validate_path.is_none() {
        skrull::bail!("fleet --validate needs a file: `fleet --validate=BENCH_fleet.json`");
    }
    if let Some(path) = validate_path {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        fb::validate_json(&text).with_context(|| format!("{path} failed validation"))?;
        println!("{path}: ok");
        return Ok(());
    }

    let mut opts = if args.flag("smoke") {
        fb::FleetBenchOptions::smoke()
    } else {
        fb::FleetBenchOptions::paper_default()
    };
    opts.jobs_per_cell = args.parse_or("jobs-per-cell", opts.jobs_per_cell)?;
    opts.seed = args.parse_or("seed", opts.seed)?;
    // worker count for the cell fan-out; 0 = auto, and any value changes
    // wall-clock only — BENCH_fleet.json is byte-identical regardless
    opts.jobs = match args.parse_or("jobs", opts.jobs)? {
        0 => fb::FleetBenchOptions::paper_default().jobs,
        n => n,
    };
    println!(
        "fleet sweep: {} arrivals × {} policies × {} pool sets, {} jobs/cell (seed {}), {} worker{}",
        opts.arrivals.len(),
        opts.policies.len(),
        opts.pool_sets.len(),
        opts.jobs_per_cell,
        opts.seed,
        opts.jobs,
        if opts.jobs == 1 { "" } else { "s" },
    );
    let sweep = fb::run_sweep(&opts)?;
    fb::print_summary(&sweep);

    let out_path = args.str_or("out", "BENCH_fleet.json");
    let json = fb::render_json(&sweep);
    fb::validate_json(&json).context("self-check of rendered BENCH_fleet.json")?;
    std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use skrull::fleet::{ArrivalPattern, FleetPolicy};
    use skrull::serve::{daemon, FaultPlan};
    use std::path::PathBuf;

    let plan = match args.get("fault-plan") {
        Some(spec) => FaultPlan::from_spec(spec)?,
        None => FaultPlan::none(),
    };

    if args.flag("smoke") {
        return daemon::run_smoke(plan);
    }

    // --record FILE: synthesize a workload and write its control log
    if let Some(out) = args.get("record") {
        let arrival = ArrivalPattern::by_name(args.str_or("arrival", "bursty"))
            .context("unknown --arrival (steady|bursty|heavy-tailed)")?;
        let policy = FleetPolicy::by_name(args.str_or("fleet-policy", "priority"))
            .context("unknown --fleet-policy (fifo|priority|shortest-priced|best-fit-price)")?;
        let pool_set = args.str_or("pool-set", "paper");
        let n_jobs: usize = args.parse_or("n-jobs", 24)?;
        let seed: u64 = args.parse_or("seed", 42)?;
        let lines = daemon::record_log(arrival, policy, pool_set, n_jobs, seed)?;
        let mut text = lines.join("\n");
        text.push('\n');
        std::fs::write(out, text).with_context(|| format!("writing {out}"))?;
        println!("recorded {} control lines to {out}", lines.len());
        return Ok(());
    }

    // --replay FILE: re-run a recorded log (daemon by default, --sim for
    // the batch simulator) and emit the cell payload — the two paths are
    // byte-identical, which CI enforces with `cmp`
    if let Some(log) = args.get("replay") {
        let text = std::fs::read_to_string(log).with_context(|| format!("reading {log}"))?;
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        let cell = if args.flag("sim") {
            daemon::replay_via_sim(&lines)?
        } else {
            let (state_dir, ephemeral) = match args.get("state-dir") {
                Some(d) => (PathBuf::from(d), false),
                None => (
                    std::env::temp_dir()
                        .join(format!("skrull_serve_replay_{}", std::process::id())),
                    true,
                ),
            };
            let cell = daemon::replay_via_daemon(&lines, &state_dir)?;
            if ephemeral {
                std::fs::remove_dir_all(&state_dir).ok();
            }
            cell
        };
        match args.get("out") {
            Some(out) => {
                let mut payload = cell;
                payload.push('\n');
                std::fs::write(out, payload).with_context(|| format!("writing {out}"))?;
                println!("wrote {out}");
            }
            None => println!("{cell}"),
        }
        return Ok(());
    }

    // daemon mode: control records from --input FILE or stdin
    let state_dir = PathBuf::from(args.str_or("state-dir", "serve-state"));
    let snapshot_every: usize = args.parse_or("snapshot-every", 64)?;
    let lines: Vec<String> = match args.get("input") {
        Some(path) => std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?
            .lines()
            .map(str::to_string)
            .collect(),
        None => {
            let mut buf = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)
                .context("reading control records from stdin")?;
            buf.lines().map(str::to_string).collect()
        }
    };
    let opts = daemon::DaemonOptions { state_dir, snapshot_every, fault: plan };
    match daemon::run(&lines, &opts)? {
        daemon::Outcome::Completed { cell_json } => {
            match args.get("out") {
                Some(out) => {
                    let mut payload = cell_json;
                    payload.push('\n');
                    std::fs::write(out, payload).with_context(|| format!("writing {out}"))?;
                    println!("wrote {out}");
                }
                None => println!("{cell_json}"),
            }
            Ok(())
        }
        daemon::Outcome::Killed => bail!(
            "fault plan killed the daemon mid-append; rerun with the same \
             --state-dir (and no kill in the plan) to recover"
        ),
    }
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    use skrull::calib;

    let mut trace_path = args.get("trace").map(str::to_string);
    if let Some(out) = args.get("emit") {
        let model = ModelSpec::by_name(args.str_or("model", "qwen2.5-0.5b"))
            .context("unknown --model (qwen2.5-0.5b | qwen2.5-7b | tiny)")?;
        let mut opts = calib::EmitOptions::default_sweep(model);
        opts.iterations = args.parse_or("iterations", opts.iterations)?;
        opts.batch_size = args.parse_or("batch-size", opts.batch_size)?;
        opts.dataset_samples = args.parse_or("samples", opts.dataset_samples)?;
        opts.seed = args.parse_or("seed", opts.seed)?;
        if let Some(d) = args.get("datasets") {
            opts.datasets = d.split(',').map(|s| s.trim().to_string()).collect();
        }
        let trace = calib::emit_calibration_sweep(&opts)?;
        calib::write_trace(out, &trace)?;
        println!("emitted {} trace records to {out}", trace.records.len());
        if trace_path.is_none() {
            trace_path = Some(out.to_string());
        }
    }
    let Some(trace_path) = trace_path else {
        skrull::bail!("calibrate needs --trace FILE (or --emit FILE to generate one)")
    };
    let trace = calib::read_trace(&trace_path)?;
    println!(
        "calibrating from {} ({} records, model {})",
        trace_path,
        trace.records.len(),
        trace.header.model
    );
    let profile = calib::calibrate(&trace)?;
    let residuals = calib::report::residuals(&trace, &profile);
    print!("{}", calib::report::render_report(&profile, &residuals));
    if let Some(out) = args.get("out") {
        calib::save_profile(out, &profile)?;
        println!("wrote {out}");
    }
    // accept both the bare flag and the `--validate=...` form e2e uses,
    // so muscle memory from one subcommand can't silently skip the gate
    if args.flag("validate") || args.get("validate").is_some() {
        let min_r2: f64 = args.parse_or("min-r2", 0.95)?;
        let tolerance: f64 = args.parse_or("tolerance", 0.05)?;
        calib::report::validate(&profile, &residuals, min_r2, tolerance)
            .with_context(|| format!("{trace_path} failed calibration validation"))?;
        println!("{trace_path}: calibration ok (r² ≥ {min_r2}, residuals ≤ {tolerance})");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let steps: usize = args.parse_or("steps", 100)?;
    let policy = Policy::by_name(args.str_or("policy", "skrull")).context("unknown --policy")?;
    // same --capacity / --hbm-gb surface as the simulation commands
    let mut mem = skrull::memplan::MemoryConfig::default();
    memory_from_args(args, &mut mem)?;
    skrull::ensure!(
        mem.hbm_gb_nodes.is_none(),
        "per-node --hbm-gb lists are not supported by train (its CP ranks are \
         time-sliced onto one device)"
    );
    // load through CostSource so the same sanity gates every other entry
    // point applies (coefficient sanity + model match) run here too; the
    // trainer always drives the tiny model
    let profile = match args.get("cost-profile") {
        Some(p) => {
            let src = skrull::config::CostSource::calibrated(p)?;
            src.ensure_model("tiny")?;
            src.profile().cloned()
        }
        None => None,
    };
    let opts = TrainerOptions {
        workers: args.parse_or("workers", 4)?,
        bucket_capacity: args.parse_or("bucket-size", 1024u32)?,
        policy,
        lr: args.parse_or("lr", 3e-3f32)?,
        seed: args.parse_or("seed", 42u64)?,
        batch_size: args.parse_or("batch-size", 16usize)?,
        capacity: mem.source,
        hbm_gb: mem.hbm_gb,
        profile,
        ..Default::default()
    };
    let corpus_cfg = CorpusConfig::tiny(512);
    let n_seqs: usize = args.parse_or("corpus-size", 512)?;
    let mut rng = Rng::seed_from_u64(opts.seed ^ 0xC0);
    let dist = LengthDistribution::LognormalMixture {
        name: "tiny-longtail",
        components: vec![(0.95, 4.6, 0.8), (0.05, 6.5, 0.4)],
        max_len: opts.bucket_capacity,
    };
    let lens: Vec<u32> = (0..n_seqs).map(|_| dist.sample(&mut rng).max(2)).collect();
    let corpus = corpus_cfg.corpus(opts.seed ^ 0x11, &lens);

    println!(
        "training tiny model for {steps} steps, policy {:?}, {} sequences",
        opts.policy,
        corpus.len()
    );
    let mut trainer = Trainer::new(artifacts, opts)?;
    println!("platform: {}", trainer.runtime.platform());
    let report = trainer.train(&corpus, steps)?;
    println!(
        "done in {} (compile {}), {} buckets, padding {:.1}%, tokens/s {:.0}",
        fmt_secs(report.wall_seconds),
        fmt_secs(report.compile_seconds),
        report.buckets_executed,
        100.0 * report.padding_fraction(),
        report.metrics.tokens_per_second(),
    );
    println!(
        "loss {:.4} -> {:.4} (entropy floor {:.4})",
        report.metrics.first_loss().unwrap_or(0.0),
        report.metrics.final_loss(10).unwrap_or(0.0),
        corpus_cfg.entropy_floor(),
    );
    print!("{}", report.metrics.render_curve(steps.div_ceil(20).max(1)));
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let n: usize = args.parse_or("samples", 200_000)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    println!("Table 1: percentage of sequence length in (synthesized) datasets, n={n}");
    println!(
        "{:<18} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}",
        "Dataset", "<1K", "<4K", "<8K", "<32K", "<128K", "Longest"
    );
    for name in ["wikipedia", "lmsys", "chatqa2"] {
        let dist = LengthDistribution::by_name(name).unwrap();
        let ds = Dataset::synthesize(&dist, n, seed);
        let f = |t: u32| 100.0 * fraction_below(&ds.lengths, t);
        println!(
            "{:<18} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>9}",
            name,
            f(1024),
            f(4096),
            f(8192),
            f(32 * 1024),
            f(128 * 1024),
            fmt_tokens(ds.max_len() as u64)
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = ModelSpec::by_name(args.str_or("model", "qwen2.5-0.5b"))
        .context("unknown --model")?;
    let p = profile::profile_model(&model, args.parse_or("dp", 4usize)?);
    println!("offline profile for {}", model.name);
    println!(
        "  T_comp  = {:.3e}·FLOPs + {:.3e}s   (r² {:.4})",
        p.comp.alpha_s_per_flop, p.comp.beta_s, p.comp.r2
    );
    println!(
        "  Memory  = {:.1} B/token (BucketSize C = {})",
        p.memory.alpha_bytes_per_token,
        fmt_tokens(p.bucket_size as u64)
    );
    println!(
        "  T_comm  = {:.3e}·V + {:.1}us   ({:.0} GB/s effective)",
        p.comm.alpha_s_per_byte,
        p.comm.fixed_s * 1e6,
        p.comm.bandwidth_gbps()
    );
    Ok(())
}

const USAGE: &str = "usage: skrull <schedule|simulate|e2e|fleet|serve|lint|sched-bench|calibrate|train|analyze|profile> [--options]
  common:    --config FILE | --model M --dataset D --dp N --cp N --batch-size K
             --policy (baseline|dacp|skrull|sorted) --bucket-size C --seed S --sync
             --shards N (scheduler shards, 0 = auto) --incremental
             --cost-profile FILE (calibrated coefficients from `skrull calibrate`)
  memory:    --capacity (fixed|hbm-derived) --hbm-gb F[,F,...] --recompute (full|selective|none)
             (accepted by schedule, simulate, e2e and train)
  streaming: --spill-dir DIR (out-of-core data plane; schedules stay byte-identical)
             --stream-ram-mb N (page-cache budget, default 64)
             (accepted by simulate and e2e)
  e2e:       --model M --datasets a,b,c --topologies 4x8,2x16 --iterations N
             --samples N --batch-size K --seed S | --seeds a,b,c --sync --epoch
             --cost-profile FILE --jobs N (0 = auto) --deterministic-timing
             --spill-dir DIR --stream-ram-mb N --sched-digest FILE (per-cell digests)
             --config FILE ([run] jobs key only) --out FILE --smoke | --validate=FILE
  fleet:     multi-tenant fleet sweep: arrivals x policies x pool sets -> BENCH_fleet.json
             --smoke --jobs-per-cell N --seed S --jobs N (0 = auto)
             --out FILE | --validate=FILE
  serve:     crash-safe fleet daemon over a JSONL control plane (stdin or --input FILE)
             --state-dir DIR (journal + snapshots; default serve-state)
             --snapshot-every N (inputs between snapshots, 0 = never; default 64)
             --fault-plan SPEC (seed=N[,kill=N:clean|torn|bitflip][,transient=N])
             --record FILE (--arrival A --fleet-policy P --pool-set S --n-jobs N --seed S)
             --replay FILE [--sim] [--out FILE] (daemon vs simulator cells are byte-identical)
             --smoke (record + replay + kill/recover in every tear mode)
  sched-bench: overhead + K-scaling sweep -> BENCH_sched_overhead.json
             --smoke --model M --dataset D --shards N (0 = auto) --out FILE | --validate=FILE
  lint:      static analysis of rust/src -> LINT_REPORT.json
             --root DIR --out FILE --validate (gate: fail on unsuppressed findings)
             --validate=FILE (check an existing report)
  calibrate: --emit FILE (run the calibration sweep; --model --datasets --iterations
             --batch-size --samples --seed shape the sweep)
             --trace FILE [--out PROFILE.json] [--validate [--min-r2 R] [--tolerance T]]
  train:     --artifacts DIR --steps N --workers W --lr F --corpus-size K
             --policy P --bucket-size C --batch-size K --seed S --cost-profile FILE
  analyze:   --samples N --seed S (Table 1 over the synthesized datasets)
  profile:   --model M --dp N (Appendix A offline-profiling fits)";

fn main() -> Result<()> {
    skrull::logging::init();
    let args = Args::from_env(&[
        "verbose",
        "sync",
        "smoke",
        "epoch",
        "validate",
        "deterministic-timing",
        "incremental",
        "sim",
    ])?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "schedule" => cmd_schedule(&args),
        "simulate" => cmd_simulate(&args),
        "e2e" => cmd_e2e(&args),
        "fleet" => cmd_fleet(&args),
        "serve" => cmd_serve(&args),
        "lint" => cmd_lint(&args),
        "sched-bench" => cmd_sched_bench(&args),
        "calibrate" => cmd_calibrate(&args),
        "train" => cmd_train(&args),
        "analyze" => cmd_analyze(&args),
        "profile" => cmd_profile(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
