//! L1 kernel measurement through the real runtime: executes the
//! Pallas-lowered attention artifact and the full train step on the CPU
//! PJRT client, reporting wall-clock and effective FLOP/s.
//!
//! interpret=True numbers are CPU-numpy-grade — NOT a TPU proxy (the
//! kernel's TPU story is the analytic VMEM/MXU estimate in EXPERIMENTS.md
//! §Perf) — but they pin the end-to-end execution cost the e2e example
//! pays per bucket, and track regressions in the lowered HLO.

use skrull::bench::{measure, TableBuilder};
use skrull::coordinator::corpus::CorpusConfig;
use skrull::data::packing::pack;
use skrull::model::ModelSpec;
use skrull::perfmodel::FlopsModel;
use skrull::runtime::Runtime;

fn main() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).join("manifest.txt").exists() {
        println!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::load(&dir).unwrap();
    let params = rt.initial_params().unwrap();
    let flops = FlopsModel::new(&ModelSpec::tiny());
    let corpus_cfg = CorpusConfig::tiny(512);

    let mut table = TableBuilder::new("L1/L2 execution on CPU PJRT (tiny model, fwd+bwd)")
        .header(&["bucket", "exec mean", "tokens/s", "GFLOP/s (est 3x fwd)"]);
    let buckets = rt.available_buckets();
    for &t in &buckets {
        rt.ensure_bucket(t).unwrap();
        let corpus = corpus_cfg.corpus(1, &[t - 2]);
        let bucket = pack(&[&corpus[0]], t as usize);
        let dev = rt.upload_params(&params).unwrap();
        let m = measure(&format!("train_step t={t}"), 2, 8, || {
            let _ = rt.train_step_on(&dev, &bucket).unwrap();
        });
        // fwd+bwd ≈ 3× forward FLOPs
        let work = 3.0 * flops.seq(t);
        table.row(&[
            t.to_string(),
            skrull::util::fmt_secs(m.mean_s()),
            format!("{:.0}", t as f64 / m.mean_s()),
            format!("{:.2}", work / m.mean_s() / 1e9),
        ]);
    }
    table.print();
    println!(
        "compile {:.1}s total for {} buckets; params upload {:.1}ms/step",
        rt.compile_seconds,
        buckets.len(),
        rt.upload_seconds * 1e3 / buckets.len() as f64
    );
}
