//! Figure 1b: attention-module performance (achieved FLOPS) under
//! different CP degrees, as a function of sequence length.
//!
//! Paper shape: higher CP degree degrades achieved FLOPS, catastrophically
//! so for short sequences (the per-rank kernel shrinks by N and by N² for
//! the attention term); for long sequences the curves converge toward the
//! device roofline.

use skrull::bench::TableBuilder;
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, FlopsModel};

fn main() {
    let spec = ModelSpec::qwen2_5_0_5b();
    let cost = CostModel::paper_default(&spec);
    let flops = FlopsModel::new(&spec);

    let seq_lens: [u32; 8] = [512, 1024, 2048, 4096, 8192, 16_384, 32_768, 65_536];
    let cp_degrees = [1usize, 2, 4, 8];

    let mut table = TableBuilder::new(
        "Figure 1b: attention achieved TFLOPS vs CP degree (Qwen2.5-0.5B, per-GPU)",
    )
    .header(&["SeqLen", "CP=1", "CP=2", "CP=4", "CP=8", "degradation 1→8"]);

    for &s in &seq_lens {
        let mut cells = vec![skrull::util::fmt_tokens(s as u64)];
        let mut tflops = Vec::new();
        for &n in &cp_degrees {
            // per-rank attention kernel: 1/N of the sequence's attention
            // FLOPs, executed at that shard's kernel efficiency
            let w = flops.attn_per_layer(s) / n as f64;
            let achieved = cost.hw.achieved_flops(w);
            tflops.push(achieved / 1e12);
            cells.push(format!("{:.1}", achieved / 1e12));
        }
        cells.push(format!("{:.1}x", tflops[0] / tflops[3]));
        table.row(&cells);
    }
    table.print();

    // The claims the paper draws from this figure, checked:
    let short_deg = {
        let w1 = flops.attn_per_layer(1024);
        cost.hw.achieved_flops(w1) / cost.hw.achieved_flops(w1 / 8.0)
    };
    let long_deg = {
        let w1 = flops.attn_per_layer(65_536);
        cost.hw.achieved_flops(w1) / cost.hw.achieved_flops(w1 / 8.0)
    };
    println!("degradation(1K, CP1→8) = {short_deg:.2}x   degradation(64K, CP1→8) = {long_deg:.2}x");
    assert!(
        short_deg > 2.0 * long_deg,
        "short sequences must suffer far more from CP than long ones"
    );
    println!("shape check OK: short sequences suffer {:.1}x more", short_deg / long_deg);
}
