//! Future-work experiment (Section 5 / 7): "We can further extend the
//! BucketSize by combining more optimization techniques like
//! parameter-efficient fine-tuning (PEFT)".
//!
//! LoRA-style PEFT frees the sharded optimizer/gradient state, enlarging
//! the activation budget and therefore BucketSize C; a larger C widens
//! Skrull's valid scheduling space.  This bench quantifies that chain on
//! the limited-speedup cell the paper calls out: Qwen2.5-7B + ChatQA2
//! (<DP=2, CP=16, B=40>), where "the major sequence length exceeds the
//! BucketSize thus leading to limited speedup".

use skrull::bench::TableBuilder;
use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, MemoryModel};

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

fn mean_iter(cfg: &ExperimentConfig, ds: &Dataset, cost: &CostModel, iters: usize) -> f64 {
    let mut loader = ScheduledLoader::new(ds, cfg);
    let mut total = 0.0;
    for _ in 0..iters {
        let (_, sched) = loader.next_iteration().expect("schedule");
        total += simulate_iteration(&sched, cost, cfg.cluster.cp).total_time;
    }
    total / iters as f64
}

fn main() {
    let iters = 30;
    let spec = ModelSpec::qwen2_5_7b();
    let base_cfg = ExperimentConfig::paper_default(spec.clone(), "chatqa2");
    let cost = CostModel::paper_default(&spec);

    // BucketSize scaling: the paper's published C=13K corresponds to the
    // full-fine-tune activation budget; PEFT's C scales by the freed
    // budget ratio (activation memory is linear in tokens, Eq. 12).
    let hbm = 80.0 * GB;
    let dp = base_cfg.cluster.dp;
    let budget_full = hbm - MemoryModel::zero2_static_bytes(&spec, dp);
    let budget_peft = hbm - MemoryModel::peft_static_bytes(&spec, dp, 0.01);
    let c_full = base_cfg.bucket_size;
    let c_peft = (c_full as f64 * budget_peft / budget_full) as u32;

    println!(
        "7B static memory: full FT {:.1} GB vs LoRA(1%) {:.1} GB of {hbm_gb:.0} GB HBM",
        MemoryModel::zero2_static_bytes(&spec, dp) / GB,
        MemoryModel::peft_static_bytes(&spec, dp, 0.01) / GB,
        hbm_gb = hbm / GB,
    );
    println!(
        "BucketSize C: {} (published) -> {} (PEFT-extended, x{:.2})\n",
        skrull::util::fmt_tokens(c_full as u64),
        skrull::util::fmt_tokens(c_peft as u64),
        c_peft as f64 / c_full as f64
    );

    let dist = LengthDistribution::chatqa2();
    let mut table = TableBuilder::new(
        "Future work: PEFT-extended BucketSize (Qwen2.5-7B, ChatQA2, <DP=2,CP=16,B=40>)",
    )
    .header(&["C", "baseline", "skrull", "skrull-refined", "speedup", "refined spd"]);

    let mut speedups = Vec::new();
    for (label, c) in [("full-FT", c_full), ("PEFT", c_peft)] {
        let mut cfg = base_cfg.clone();
        cfg.bucket_size = c;
        let ds = Dataset::synthesize(&dist, 100_000, cfg.seed ^ 0xD5)
            .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
        cfg.policy = Policy::Baseline;
        let t_base = mean_iter(&cfg, &ds, &cost, iters);
        cfg.policy = Policy::Skrull;
        let t_skrull = mean_iter(&cfg, &ds, &cost, iters);
        cfg.policy = Policy::SkrullRefined;
        let t_ref = mean_iter(&cfg, &ds, &cost, iters);
        let spd = t_base / t_skrull;
        let spd_ref = t_base / t_ref;
        speedups.push(spd_ref);
        table.row(&[
            format!("{label} ({})", skrull::util::fmt_tokens(c as u64)),
            skrull::util::fmt_secs(t_base),
            skrull::util::fmt_secs(t_skrull),
            skrull::util::fmt_secs(t_ref),
            format!("{spd:.2}x"),
            format!("{spd_ref:.2}x"),
        ]);
    }
    table.print();
    println!(
        "PEFT-extended C lifts the refined speedup {:.2}x -> {:.2}x on the paper's hardest cell",
        speedups[0], speedups[1]
    );
    assert!(
        speedups[1] >= speedups[0] * 0.98,
        "larger scheduling space must not hurt"
    );
}
