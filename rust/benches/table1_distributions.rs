//! Table 1 + Figure 1a: sequence-length distributions of the three
//! Long-SFT datasets.  Regenerates the paper's percentile table from the
//! synthetic generators and prints an ASCII log-scale histogram per
//! dataset (the Fig. 1a view).

use skrull::data::{Dataset, LengthDistribution};
use skrull::util::stats::fraction_below;
use skrull::util::fmt_tokens;

/// Paper's Table 1 (percent below each threshold, longest).
const PAPER: &[(&str, [f64; 5], &str)] = &[
    ("wikipedia", [87.88, 99.34, 99.92, 99.99, 100.0], "78K"),
    ("lmsys", [87.12, 99.35, 99.87, 99.98, 99.99], "1643K"),
    ("chatqa2", [21.92, 31.48, 40.43, 99.86, 100.0], "99K"),
];

const THRESHOLDS: [u32; 5] = [1 << 10, 4 << 10, 8 << 10, 32 << 10, 128 << 10];

fn histogram(lengths: &[u32]) -> String {
    // log2 bins from 64 to 256K
    let mut bins = [0usize; 13];
    for &l in lengths {
        let mut b = 0usize;
        let mut edge = 64u32;
        while l > edge && b < 12 {
            edge = edge.saturating_mul(2);
            b += 1;
        }
        bins[b] += 1;
    }
    let max = *bins.iter().max().unwrap_or(&1);
    let mut out = String::new();
    let mut edge = 64u64;
    for &count in &bins {
        let bar = "#".repeat((count * 48 + max - 1) / max.max(1));
        out.push_str(&format!("  ≤{:>6} {:>7} {}\n", fmt_tokens(edge), count, bar));
        edge *= 2;
    }
    out
}

fn main() {
    let n = 200_000;
    println!("== Table 1: Percentage of sequence length in real-world datasets ==");
    println!(
        "{:<12} {:>22} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}",
        "Dataset", "", "<1K", "<4K", "<8K", "<32K", "<128K", "Longest"
    );
    for (name, paper_pcts, paper_longest) in PAPER {
        let dist = LengthDistribution::by_name(name).unwrap();
        let ds = Dataset::synthesize(&dist, n, 42);
        let ours: Vec<f64> = THRESHOLDS
            .iter()
            .map(|&t| 100.0 * fraction_below(&ds.lengths, t))
            .collect();
        println!(
            "{:<12} {:>22} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>9}",
            name, "paper", paper_pcts[0], paper_pcts[1], paper_pcts[2], paper_pcts[3], paper_pcts[4], paper_longest
        );
        println!(
            "{:<12} {:>22} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>9}",
            "",
            "ours (synthesized)",
            ours[0],
            ours[1],
            ours[2],
            ours[3],
            ours[4],
            fmt_tokens(ds.max_len() as u64)
        );
        let max_dev = ours
            .iter()
            .zip(paper_pcts)
            .map(|(o, p)| (o - p).abs())
            .fold(0.0, f64::max);
        println!("{:<12} {:>22} max deviation {max_dev:.2} pp", "", "");
    }
    println!("\n== Figure 1a: sequence length histograms (log2 bins) ==");
    for (name, _, _) in PAPER {
        let dist = LengthDistribution::by_name(name).unwrap();
        let ds = Dataset::synthesize(&dist, n, 42);
        println!("{name}:");
        print!("{}", histogram(&ds.lengths));
    }
    println!("note: lmsys longest is truncated to the 128K context window (DESIGN.md §2)");
}
