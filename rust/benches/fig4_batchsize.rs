//! Figure 4: speedup vs batch size (ChatQA2-Long-SFT, Qwen2.5-0.5B).
//!
//! Paper shape: speedup grows with batch size (larger scheduling scope for
//! GDS) then stabilizes as sampled batches converge to the dataset's
//! length distribution.

use skrull::bench::TableBuilder;
use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;

fn mean_iter_time(cfg: &ExperimentConfig, ds: &Dataset, cost: &CostModel, iters: usize) -> f64 {
    let mut loader = ScheduledLoader::new(ds, cfg);
    let mut total = 0.0;
    for _ in 0..iters {
        let (_, sched) = loader.next_iteration().expect("schedule");
        total += simulate_iteration(&sched, cost, cfg.cluster.cp).total_time;
    }
    total / iters as f64
}

fn main() {
    let iters = 30;
    let base_cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "chatqa2");
    let dist = LengthDistribution::chatqa2();
    let ds = Dataset::synthesize(&dist, 100_000, base_cfg.seed ^ 0xD5)
        .truncated(base_cfg.bucket_size * base_cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&base_cfg.model);

    let mut table = TableBuilder::new("Figure 4: speedup vs batch size (ChatQA2, Qwen2.5-0.5B)")
        .header(&["BatchSize", "baseline", "skrull", "speedup", "+refine", "refine spd"]);
    let mut speedups = Vec::new();
    let mut speedups_ref = Vec::new();
    let batch_sizes = [8usize, 16, 24, 32, 40, 48, 56, 64];
    for &b in &batch_sizes {
        let mut cfg = base_cfg.clone();
        cfg.cluster.batch_size = b;
        cfg.policy = Policy::Baseline;
        let t_base = mean_iter_time(&cfg, &ds, &cost, iters);
        cfg.policy = Policy::Skrull;
        let t_skrull = mean_iter_time(&cfg, &ds, &cost, iters);
        cfg.policy = Policy::SkrullRefined;
        let t_ref = mean_iter_time(&cfg, &ds, &cost, iters);
        let spd = t_base / t_skrull;
        let spd_ref = t_base / t_ref;
        speedups.push(spd);
        speedups_ref.push(spd_ref);
        table.row(&[
            b.to_string(),
            skrull::util::fmt_secs(t_base),
            skrull::util::fmt_secs(t_skrull),
            format!("{spd:.2}x"),
            skrull::util::fmt_secs(t_ref),
            format!("{spd_ref:.2}x"),
        ]);
    }
    table.print();

    // Shape: speedup grows with scheduling scope.  Plain Alg.1 can dip
    // below 1x at tiny batches (few sequences per rank ⇒ avoid-sharding
    // keeps whole long sequences on single ranks while the baseline at
    // least shards them); the cost-aware refinement removes that dip —
    // the same weakness the solver-gap ablation quantifies.
    let first = speedups[0];
    let last = *speedups.last().unwrap();
    println!("skrull: {first:.2}x @B=8 → {last:.2}x @B=64");
    println!(
        "refined: {:.2}x @B=8 → {:.2}x @B=64",
        speedups_ref[0],
        speedups_ref.last().unwrap()
    );
    assert!(last > first, "speedup must grow with scheduling scope");
    assert!(
        speedups_ref.iter().all(|&s| s > 0.95),
        "refined policy must not lose to baseline at any batch size"
    );
    assert!(speedups_ref.last().unwrap() > &speedups_ref[0]);
    println!("shape check OK: speedup grows with batch size then stabilizes");
}
