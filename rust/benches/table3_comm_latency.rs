//! Table 3: collective-communication latency profiling.
//!
//! Regenerates the paper's latency table from the fitted network model
//! (Eq. 16) next to the paper's measured values, with per-point residuals
//! — this is the calibration evidence for the simulator's network.

use skrull::bench::TableBuilder;
use skrull::perfmodel::comm::{
    CommModel, TABLE3_ALL_GATHER, TABLE3_ALL_TO_ALL, TABLE3_REDUCE_SCATTER,
};

const MIB: f64 = 1024.0 * 1024.0;

fn report(name: &str, points: &[(f64, f64)]) {
    let m = CommModel::fit(points);
    let mut t = TableBuilder::new(&format!(
        "Table 3 [{name}]: α = {:.3e} s/B ({:.0} GB/s), T_fixed = {:.1} µs",
        m.alpha_s_per_byte,
        m.bandwidth_gbps(),
        m.fixed_s * 1e6
    ))
    .header(&["Size (MiB)", "paper (µs)", "model (µs)", "error"]);
    let mut worst: f64 = 0.0;
    for &(mib, us) in points {
        let pred = m.latency(mib * MIB) * 1e6;
        let rel = (pred - us) / us * 100.0;
        worst = worst.max(rel.abs());
        t.row(&[
            format!("{mib:.0}"),
            format!("{us:.1}"),
            format!("{pred:.1}"),
            format!("{rel:+.1}%"),
        ]);
    }
    t.print();
    println!("worst-case relative error: {worst:.1}%\n");
    assert!(worst < 40.0, "{name}: comm model fit degraded ({worst:.1}%)");
}

fn main() {
    report("all_gather", TABLE3_ALL_GATHER);
    report("all_to_all", TABLE3_ALL_TO_ALL);
    report("reduce_scatter", TABLE3_REDUCE_SCATTER);
    println!("(Eq. 16 behaviour: fixed overhead dominates <8 MiB, bandwidth beyond)");
}
