//! Ablations of the design choices DESIGN.md calls out:
//!   1. heuristic vs exact solver — TDACP optimality gap at small K
//!   2. comm/comp overlap (Eq. 2) on/off
//!   3. GDS long/short interleaving on/off
//!   4. roll-back victim choice: largest (ours) vs first-found (paper Alg. 3)

use skrull::cluster::simulate_iteration;
use skrull::bench::TableBuilder;
use skrull::config::ExperimentConfig;
use skrull::data::{Dataset, LengthDistribution};
use skrull::data::loader::ScheduledLoader;
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, FlopsModel};
use skrull::rng::Rng;
use skrull::scheduler::dacp::{self, DacpConfig};
use skrull::scheduler::gds::GdsConfig;
use skrull::scheduler::{gds, solver};

/// 1. Heuristic-vs-optimal gap on random small micro-batches.
fn ablation_solver_gap() {
    let spec = ModelSpec::qwen2_5_0_5b();
    let cost = CostModel::paper_default(&spec);
    let flops = FlopsModel::new(&spec);
    let dist = LengthDistribution::chatqa2();
    let mut rng = Rng::seed_from_u64(1234);
    let (c, n) = (26 * 1024u32, 4usize);
    let cfg = DacpConfig::new(c, n);

    let mut gaps = Vec::new();
    let mut gaps_refined = Vec::new();
    let mut nodes_total = 0u64;
    let mut nodes_warm_total = 0u64;
    let trials = 40;
    for _ in 0..trials {
        let k = 3 + rng.usize_below(6); // K in 3..8
        let lens: Vec<u32> = (0..k).map(|_| dist.sample(&mut rng).min(c * n as u32)).collect();
        let Ok(hplan) = dacp::schedule(&lens, &cfg, &flops) else { continue };
        let Some(sol) = solver::solve(&lens, c, n, &cost, 5_000_000) else { continue };
        // warm-starting from the heuristic incumbent prunes the search
        // without moving the optimum (solver property tests pin this)
        let warm = solver::solve_warm(&lens, c, n, &cost, 5_000_000, Some(&hplan))
            .expect("warm search explores a subset of the cold search");
        assert!((warm.cost - sol.cost).abs() <= 1e-9 * sol.cost.max(1.0));
        assert!(warm.nodes <= sol.nodes);
        let h = cost.tdacp(&lens, &hplan, n);
        let refined = dacp::refine_multistart(&hplan, &lens, &cfg, &cost);
        let hr = cost.tdacp(&lens, &refined, n);
        gaps.push(h / sol.cost);
        gaps_refined.push(hr / sol.cost);
        nodes_total += sol.nodes;
        nodes_warm_total += warm.nodes;
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let worst = gaps.iter().cloned().fold(0.0, f64::max);
    let worst_r = gaps_refined.iter().cloned().fold(0.0, f64::max);
    println!("== Ablation 1: DACP heuristic vs exact solver ({} instances) ==", gaps.len());
    println!(
        "Alg.1 heuristic:        mean TDACP ratio {:.4} (1.0 = optimal), worst {worst:.3}",
        mean(&gaps)
    );
    println!(
        "+ cost-aware refine:    mean TDACP ratio {:.4}, worst {worst_r:.3}   (our extension)",
        mean(&gaps_refined)
    );
    println!(
        "solver nodes explored: {nodes_total} cold, {nodes_warm_total} warm-started \
         ({:.0}% pruned by the heuristic incumbent)",
        100.0 * (1.0 - nodes_warm_total as f64 / nodes_total.max(1) as f64)
    );
    println!(
        "finding: Alg.1's avoid-sharding principle leaves isolated long locals\n\
         dominating the makespan; one greedy demote/migrate pass closes most of the gap."
    );
    assert!(gaps.iter().all(|&g| g >= 1.0 - 1e-9), "heuristic cannot beat the optimum");
    assert!(mean(&gaps_refined) < 1.1, "refined gap too large: {}", mean(&gaps_refined));
    assert!(mean(&gaps_refined) <= mean(&gaps) + 1e-9);
    println!("gap check OK (refined mean within 10% of optimal)\n");
}

/// 2. Eq. 2 overlap on/off: how much of Skrull's win comes from hiding
/// CP communication under local computation.
fn ablation_overlap() {
    let spec = ModelSpec::qwen2_5_0_5b();
    let cost = CostModel::paper_default(&spec);
    let flops = FlopsModel::new(&spec);
    let dist = LengthDistribution::chatqa2();
    let mut rng = Rng::seed_from_u64(77);
    let gcfg = GdsConfig::new(26 * 1024, 8, 4);
    let ds = Dataset::synthesize(&dist, 50_000, 3).truncated(26 * 1024 * 8);

    let mut with = 0.0;
    let mut without = 0.0;
    for _ in 0..20 {
        let batch = ds.sample_batch(&mut rng, 64);
        let sched = gds::schedule(&batch, &gcfg, &flops).unwrap();
        for rank in &sched.ranks {
            for mb in &rank.micro_batches {
                let lens = mb.lens();
                let times = cost.rank_times(&lens, &mb.plan, 8);
                for t in &times {
                    with += t.total;
                    // no-overlap variant: comm serializes before local comp
                    without += t.local_comp + t.comm + t.dist_comp
                        + (t.total - t.local_comp.max(t.comm) - t.dist_comp);
                }
            }
        }
    }
    println!("== Ablation 2: comm/comp overlap (Eq. 2) ==");
    println!(
        "aggregate rank-time with overlap {:.3}s, without {:.3}s  ({:.1}% saved)",
        with,
        without,
        100.0 * (without - with) / without
    );
    assert!(with <= without + 1e-9);
    println!("overlap check OK\n");
}

/// 3. GDS interleaved pairing vs contiguous chunking.
fn ablation_interleave() {
    let spec = ModelSpec::qwen2_5_0_5b();
    let cost = CostModel::paper_default(&spec);
    let flops = FlopsModel::new(&spec);
    let ds = Dataset::synthesize(&LengthDistribution::chatqa2(), 50_000, 5)
        .truncated(26 * 1024 * 8);
    let mut rng = Rng::seed_from_u64(11);

    let mut t_inter = 0.0;
    let mut t_chunk = 0.0;
    for _ in 0..20 {
        let batch = ds.sample_batch(&mut rng, 64);
        let mut cfg = GdsConfig::new(26 * 1024, 8, 4);
        cfg.interleave = true;
        let s1 = gds::schedule(&batch, &cfg, &flops).unwrap();
        cfg.interleave = false;
        let s2 = gds::schedule(&batch, &cfg, &flops).unwrap();
        t_inter += simulate_iteration(&s1, &cost, 8).total_time;
        t_chunk += simulate_iteration(&s2, &cost, 8).total_time;
    }
    println!("== Ablation 3: GDS long/short pairing ==");
    println!(
        "interleaved {:.3}s vs contiguous {:.3}s over 20 iterations ({:+.1}%)",
        t_inter,
        t_chunk,
        100.0 * (t_chunk - t_inter) / t_chunk
    );
    println!("(paper principle ii: pairing spreads long sequences across micro-batches)\n");
}

/// 4. Roll-back victim choice.
fn ablation_rollback() {
    let spec = ModelSpec::qwen2_5_0_5b();
    let flops = FlopsModel::new(&spec);
    let cost = CostModel::paper_default(&spec);
    let dist = LengthDistribution::chatqa2();
    let mut rng = Rng::seed_from_u64(21);
    let (c, n) = (13 * 1024u32, 8usize);

    let mut wins_largest = 0;
    let mut wins_first = 0;
    let mut both_ok = 0;
    let trials = 200;
    for _ in 0..trials {
        let k = 4 + rng.usize_below(8);
        // tight workloads: scale so total ≈ 0.9 × C·N (rollback territory)
        let mut lens: Vec<u32> = (0..k).map(|_| dist.sample(&mut rng)).collect();
        let total: u64 = lens.iter().map(|&l| l as u64).sum();
        let scale = 0.9 * (c as f64 * n as f64) / total as f64;
        for l in &mut lens {
            *l = ((*l as f64 * scale) as u32).clamp(1, c * n as u32);
        }
        let mut cfg = DacpConfig::new(c, n);
        cfg.rollback_largest = true;
        let a = dacp::schedule(&lens, &cfg, &flops);
        cfg.rollback_largest = false;
        let b = dacp::schedule(&lens, &cfg, &flops);
        if let (Ok(pa), Ok(pb)) = (&a, &b) {
            both_ok += 1;
            let ta = cost.tdacp(&lens, pa, n);
            let tb = cost.tdacp(&lens, pb, n);
            if ta < tb * 0.999 {
                wins_largest += 1;
            } else if tb < ta * 0.999 {
                wins_first += 1;
            }
        }
    }
    println!("== Ablation 4: roll-back victim (largest vs paper's first-found) ==");
    println!(
        "{both_ok}/{trials} tight instances schedulable by both; largest wins {wins_largest}, first wins {wins_first}, ties {}",
        both_ok - wins_largest - wins_first
    );
    println!();
}

/// 5. End-to-end: how much each Skrull component contributes (a compact
/// rerun of Fig. 3's step-by-step on one config).
fn ablation_step_by_step() {
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "lmsys");
    let ds = Dataset::synthesize(&LengthDistribution::lmsys_chat(), 100_000, 1)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg.model);
    let mut t = TableBuilder::new("Ablation 5: component contributions (lmsys, 0.5B, 20 iters)")
        .header(&["policy", "mean iter", "speedup"]);
    let mut base = None;
    for policy in [
        skrull::config::Policy::Baseline,
        skrull::config::Policy::SortedBatching,
        skrull::config::Policy::DacpOnly,
        skrull::config::Policy::Skrull,
    ] {
        let mut pcfg = cfg.clone();
        pcfg.policy = policy;
        let mut loader = ScheduledLoader::new(&ds, &pcfg);
        let mut total = 0.0;
        for _ in 0..20 {
            let (_, sched) = loader.next_iteration().unwrap();
            total += simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time;
        }
        let mean = total / 20.0;
        let b = *base.get_or_insert(mean);
        t.row(&[policy.name().to_string(), skrull::util::fmt_secs(mean), format!("{:.2}x", b / mean)]);
    }
    t.print();
}

fn main() {
    ablation_solver_gap();
    ablation_overlap();
    ablation_interleave();
    ablation_rollback();
    ablation_step_by_step();
}
