//! Section 4.3's "near-zero cost online scheduling" claim: wall-clock of
//! the full GDS+DACP scheduler per iteration vs the simulated iteration
//! time it schedules, across batch sizes (and a large-K stress sweep).
//!
//! Pass criterion (paper's claim): scheduling < 1% of iteration time at
//! the paper's settings.
//!
//! Besides the human-readable table this bench emits
//! `BENCH_sched_overhead.json` (per-K mean/p50 scheduling time, overhead
//! ratio, and fast-path-vs-reference speedup) so the perf trajectory is
//! machine-trackable across PRs.

use std::fmt::Write as _;

use skrull::bench::{measure, Measurement, TableBuilder};
use skrull::cluster::simulate_iteration;
use skrull::config::ExperimentConfig;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, FlopsModel};
use skrull::rng::Rng;
use skrull::scheduler::gds::{self, GdsConfig, SchedCtx};

struct Row {
    k: usize,
    fast: Measurement,
    refined: Measurement,
    reference: Measurement,
    iter_time_s: f64,
    overhead_ratio: f64,
}

fn json_escape_free(s: &str) -> &str {
    // all strings we emit are identifier-ish; keep the writer honest
    assert!(!s.contains(['"', '\\', '\n']), "unescapable: {s}");
    s
}

fn write_json(cfg: &ExperimentConfig, rows: &[Row], worst_ratio: f64) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"sched_overhead\",");
    let _ = writeln!(out, "  \"schema_version\": 1,");
    let _ = writeln!(
        out,
        "  \"config\": {{\"model\": \"{}\", \"dataset\": \"{}\", \"dp\": {}, \"cp\": {}, \"bucket_size\": {}}},",
        json_escape_free(&cfg.model.name),
        json_escape_free(&cfg.dataset),
        cfg.cluster.dp,
        cfg.cluster.cp,
        cfg.bucket_size
    );
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(
            out,
            "    {{\"k\": {}, \"sched_mean_s\": {:e}, \"sched_p50_s\": {:e}, \"refine_mean_s\": {:e}, \
             \"reference_mean_s\": {:e}, \"speedup_vs_reference\": {:.3}, \"iter_time_s\": {:e}, \
             \"overhead_ratio\": {:e}}}{}",
            r.k,
            r.fast.mean_s(),
            r.fast.samples.quantile(0.5),
            r.refined.mean_s(),
            r.reference.mean_s(),
            r.reference.mean_s() / r.fast.mean_s().max(1e-12),
            r.iter_time_s,
            r.overhead_ratio,
            if i + 1 == rows.len() { "" } else { "," }
        );
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"worst_paper_scale_ratio\": {:e},", worst_ratio);
    let _ = writeln!(
        out,
        "  \"near_zero_overhead_pass\": {}",
        worst_ratio < 0.01
    );
    out.push_str("}\n");
    out
}

fn main() {
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    let dist = LengthDistribution::wikipedia();
    let ds = Dataset::synthesize(&dist, 100_000, 7).truncated(cfg.bucket_size * 8);
    let cost = CostModel::paper_default(&cfg.model);
    let flops = FlopsModel::new(&cfg.model);
    let gcfg = GdsConfig::new(cfg.bucket_size, cfg.cluster.cp, cfg.cluster.dp);

    let mut table = TableBuilder::new("Scheduler overhead (GDS+DACP, Qwen2.5-0.5B, wikipedia)")
        .header(&["BatchSize K", "sched time", "+refine", "reference", "speedup", "iter time (sim)", "overhead"]);

    let mut rng = Rng::seed_from_u64(99);
    let mut worst_ratio: f64 = 0.0;
    let mut rows: Vec<Row> = Vec::new();
    let mut ctx = SchedCtx::default();
    for k in [16usize, 64, 256, 1024, 4096] {
        let batch = ds.sample_batch(&mut rng, k);
        // fewer samples at stress scale — the reference path is the
        // pre-fast-path scheduler and is deliberately slow there
        let (warmup, samples) = if k <= 256 { (3, 20) } else { (1, 5) };
        let m = measure(&format!("gds k={k}"), warmup, samples, || {
            let _ = gds::schedule_with_ctx(&batch, &gcfg, &flops, &mut ctx).expect("schedule");
        });
        let m_ref = measure(&format!("gds+refine k={k}"), warmup, samples, || {
            let _ = gds::schedule_refined_with_ctx(&batch, &gcfg, &cost, &mut ctx)
                .expect("schedule");
        });
        let m_reference = measure(&format!("gds reference k={k}"), warmup.min(1), samples.min(5), || {
            let _ = gds::schedule_reference(&batch, &gcfg, &flops).expect("schedule");
        });
        let sched = gds::schedule(&batch, &gcfg, &flops).unwrap();
        let iter_time = simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time;
        let ratio = m.mean_s() / iter_time;
        if k <= 64 {
            worst_ratio = worst_ratio.max(ratio);
        }
        table.row(&[
            k.to_string(),
            skrull::util::fmt_secs(m.mean_s()),
            skrull::util::fmt_secs(m_ref.mean_s()),
            skrull::util::fmt_secs(m_reference.mean_s()),
            format!("{:.1}x", m_reference.mean_s() / m.mean_s().max(1e-12)),
            skrull::util::fmt_secs(iter_time),
            format!("{:.3}%", 100.0 * ratio),
        ]);
        rows.push(Row {
            k,
            fast: m,
            refined: m_ref,
            reference: m_reference,
            iter_time_s: iter_time,
            overhead_ratio: ratio,
        });
    }
    table.print();
    println!("worst overhead at paper-scale batches (K≤64): {:.3}%", 100.0 * worst_ratio);
    if let Some(stress) = rows.last() {
        println!(
            "fast-path speedup vs reference at K={}: {:.1}x",
            stress.k,
            stress.reference.mean_s() / stress.fast.mean_s().max(1e-12)
        );
    }

    let json = write_json(&cfg, &rows, worst_ratio);
    std::fs::write("BENCH_sched_overhead.json", &json).expect("write BENCH_sched_overhead.json");
    println!("wrote BENCH_sched_overhead.json");

    assert!(
        worst_ratio < 0.01,
        "near-zero-overhead claim violated: {:.3}%",
        100.0 * worst_ratio
    );
    println!("near-zero-overhead claim holds (<1%)");

    // component microbenches
    println!();
    let batch = ds.sample_batch(&mut rng, 64);
    let lens: Vec<u32> = batch.iter().map(|s| s.len).collect();
    let dcfg = skrull::scheduler::dacp::DacpConfig::new(cfg.bucket_size, cfg.cluster.cp);
    println!(
        "{}",
        measure("dacp alone (K=64 micro-batch)", 10, 100, || {
            let _ = skrull::scheduler::dacp::schedule(&lens, &dcfg, &flops);
        })
        .report()
    );
    println!(
        "{}",
        measure("binpack alone (K=64, ws=4)", 10, 100, || {
            let weighted: Vec<(u64, f64)> =
                batch.iter().map(|s| (s.id, flops.seq(s.len))).collect();
            let _ = skrull::scheduler::binpack::balance(&weighted, 4);
        })
        .report()
    );
}
