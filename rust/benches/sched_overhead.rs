//! Thin wrapper over `bench::sched_overhead` (also reachable as
//! `skrull sched-bench`): run the overhead + K-scaling sweeps at paper
//! scale, emit `BENCH_sched_overhead.json`, and self-validate it with the
//! same gate CI uses.

use skrull::bench::{measure, sched_overhead};
use skrull::perfmodel::FlopsModel;
use skrull::rng::Rng;

fn main() {
    let opts = sched_overhead::SchedBenchOptions::paper_default();
    let report = sched_overhead::run(&opts).expect("sched_overhead bench");
    sched_overhead::print_report(&report);

    let json = sched_overhead::render_json(&report);
    std::fs::write("BENCH_sched_overhead.json", &json).expect("write BENCH_sched_overhead.json");
    println!("wrote BENCH_sched_overhead.json");
    sched_overhead::validate_json(&json).expect("BENCH_sched_overhead.json failed its own gate");
    println!("near-zero-overhead claim holds (<1%) and K-scaling is near-linear");

    // component microbenches
    println!();
    let cfg = report.cfg;
    let dist = skrull::data::LengthDistribution::wikipedia();
    let ds = skrull::data::Dataset::synthesize(&dist, 100_000, 7)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let flops = FlopsModel::new(&cfg.model);
    let mut rng = Rng::seed_from_u64(99);
    let batch = ds.sample_batch(&mut rng, 64);
    let lens: Vec<u32> = batch.iter().map(|s| s.len).collect();
    let dcfg = skrull::scheduler::dacp::DacpConfig::new(cfg.bucket_size, cfg.cluster.cp);
    println!(
        "{}",
        measure("dacp alone (K=64 micro-batch)", 10, 100, || {
            let _ = skrull::scheduler::dacp::schedule(&lens, &dcfg, &flops);
        })
        .report()
    );
    println!(
        "{}",
        measure("binpack alone (K=64, ws=4)", 10, 100, || {
            let weighted: Vec<(u64, f64)> =
                batch.iter().map(|s| (s.id, flops.seq(s.len))).collect();
            let _ = skrull::scheduler::binpack::balance(&weighted, 4);
        })
        .report()
    );
}
