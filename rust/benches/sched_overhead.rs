//! Section 4.3's "near-zero cost online scheduling" claim: wall-clock of
//! the full GDS+DACP scheduler per iteration vs the simulated iteration
//! time it schedules, across batch sizes (and a large-K stress sweep).
//!
//! Pass criterion (paper's claim): scheduling < 1% of iteration time at
//! the paper's settings.

use skrull::bench::{measure, TableBuilder};
use skrull::cluster::simulate_iteration;
use skrull::config::ExperimentConfig;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::{CostModel, FlopsModel};
use skrull::rng::Rng;
use skrull::scheduler::gds::{self, GdsConfig};

fn main() {
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    let dist = LengthDistribution::wikipedia();
    let ds = Dataset::synthesize(&dist, 100_000, 7).truncated(cfg.bucket_size * 8);
    let cost = CostModel::paper_default(&cfg.model);
    let flops = FlopsModel::new(&cfg.model);
    let gcfg = GdsConfig::new(cfg.bucket_size, cfg.cluster.cp, cfg.cluster.dp);

    let mut table = TableBuilder::new("Scheduler overhead (GDS+DACP, Qwen2.5-0.5B, wikipedia)")
        .header(&["BatchSize K", "sched time", "+refine", "iter time (sim)", "overhead"]);

    let mut rng = Rng::seed_from_u64(99);
    let mut worst_ratio: f64 = 0.0;
    for k in [16usize, 64, 256, 1024, 4096] {
        let batch = ds.sample_batch(&mut rng, k);
        let m = measure(&format!("gds k={k}"), 3, 20, || {
            let _ = gds::schedule(&batch, &gcfg, &flops).expect("schedule");
        });
        let m_ref = measure(&format!("gds+refine k={k}"), 3, 20, || {
            let _ = gds::schedule_refined(&batch, &gcfg, &cost).expect("schedule");
        });
        let sched = gds::schedule(&batch, &gcfg, &flops).unwrap();
        let iter_time = simulate_iteration(&sched, &cost, cfg.cluster.cp).total_time;
        let ratio = m.mean_s() / iter_time;
        if k <= 64 {
            worst_ratio = worst_ratio.max(ratio);
        }
        table.row(&[
            k.to_string(),
            skrull::util::fmt_secs(m.mean_s()),
            skrull::util::fmt_secs(m_ref.mean_s()),
            skrull::util::fmt_secs(iter_time),
            format!("{:.3}%", 100.0 * ratio),
        ]);
    }
    table.print();
    println!("worst overhead at paper-scale batches (K≤64): {:.3}%", 100.0 * worst_ratio);
    assert!(
        worst_ratio < 0.01,
        "near-zero-overhead claim violated: {:.3}%",
        100.0 * worst_ratio
    );
    println!("near-zero-overhead claim holds (<1%)");

    // component microbenches
    println!();
    let batch = ds.sample_batch(&mut rng, 64);
    let lens: Vec<u32> = batch.iter().map(|s| s.len).collect();
    let dcfg = skrull::scheduler::dacp::DacpConfig::new(cfg.bucket_size, cfg.cluster.cp);
    println!(
        "{}",
        measure("dacp alone (K=64 micro-batch)", 10, 100, || {
            let _ = skrull::scheduler::dacp::schedule(&lens, &dcfg, &flops);
        })
        .report()
    );
    println!(
        "{}",
        measure("binpack alone (K=64, ws=4)", 10, 100, || {
            let weighted: Vec<(u64, f64)> =
                batch.iter().map(|s| (s.id, flops.seq(s.len))).collect();
            let _ = skrull::scheduler::binpack::balance(&weighted, 4);
        })
        .report()
    );
}
