//! Figure 3: overall performance and step-by-step evaluation.
//!
//! For each (model, dataset) pair of the paper's evaluation: 30 simulated
//! iterations under DeepSpeed-baseline / +DACP / full Skrull (plus the
//! LongAlign sorted-batching comparator), reporting mean iteration time
//! and speedup — the same lanes as the paper's bars.
//!
//! Paper numbers for reference: Skrull vs DeepSpeed averages 3.76x
//! (peak 7.54x); 0.5B avg 5.50x, 7B avg 2.03x; long-tail datasets gain
//! more than the bimodal ChatQA2.

use skrull::bench::TableBuilder;
use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;

struct Lane {
    policy: Policy,
    mean_iter: f64,
    utilization: f64,
}

fn run_config(model: ModelSpec, dataset: &str, iters: usize) -> (ExperimentConfig, Vec<Lane>) {
    let cfg = ExperimentConfig::paper_default(model, dataset);
    let dist = LengthDistribution::by_name(dataset).unwrap();
    let ds = Dataset::synthesize(&dist, 100_000, cfg.seed ^ 0xD5)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg.model);

    let lanes = [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SkrullRefined, Policy::SortedBatching]
        .into_iter()
        .map(|policy| {
            let mut pcfg = cfg.clone();
            pcfg.policy = policy;
            let mut loader = ScheduledLoader::new(&ds, &pcfg);
            let mut total = 0.0;
            let mut util = 0.0;
            for _ in 0..iters {
                let (_, sched) = loader.next_iteration().expect("schedule");
                let sim = simulate_iteration(&sched, &cost, cfg.cluster.cp);
                total += sim.total_time;
                util += sim.compute_utilization;
            }
            Lane {
                policy,
                mean_iter: total / iters as f64,
                utilization: util / iters as f64,
            }
        })
        .collect();
    (cfg, lanes)
}

fn main() {
    let iters = 30;
    let configs = [
        (ModelSpec::qwen2_5_0_5b(), "wikipedia"),
        (ModelSpec::qwen2_5_0_5b(), "lmsys"),
        (ModelSpec::qwen2_5_0_5b(), "chatqa2"),
        (ModelSpec::qwen2_5_7b(), "wikipedia"),
        (ModelSpec::qwen2_5_7b(), "lmsys"),
        (ModelSpec::qwen2_5_7b(), "chatqa2"),
    ];

    let mut table = TableBuilder::new("Figure 3: overall + step-by-step (30 iterations each)")
        .header(&[
            "Model", "Dataset", "Setting", "baseline", "+DACP", "Skrull", "+refine", "sorted",
            "DACP spd", "Skrull spd", "util b→s",
        ]);

    let mut speedups = Vec::new();
    let mut per_model: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for (model, dataset) in configs {
        let name = model.name;
        let (cfg, lanes) = run_config(model, dataset, iters);
        let base = lanes[0].mean_iter;
        let skrull = lanes[2].mean_iter;
        let spd_dacp = base / lanes[1].mean_iter;
        let spd_skrull = base / skrull;
        speedups.push(spd_skrull);
        per_model.entry(name).or_default().push(spd_skrull);
        table.row(&[
            name.to_string(),
            dataset.to_string(),
            format!(
                "<DP={},CP={},B={}>",
                cfg.cluster.dp, cfg.cluster.cp, cfg.cluster.batch_size
            ),
            skrull::util::fmt_secs(base),
            skrull::util::fmt_secs(lanes[1].mean_iter),
            skrull::util::fmt_secs(skrull),
            skrull::util::fmt_secs(lanes[3].mean_iter),
            skrull::util::fmt_secs(lanes[4].mean_iter),
            format!("{spd_dacp:.2}x"),
            format!("{spd_skrull:.2}x"),
            format!("{:.0}%→{:.0}%", 100.0 * lanes[0].utilization, 100.0 * lanes[2].utilization),
        ]);
    }
    table.print();

    let avg: f64 = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let peak = speedups.iter().cloned().fold(0.0, f64::max);
    println!("ours:  average speedup {avg:.2}x, peak {peak:.2}x");
    println!("paper: average speedup 3.76x, peak 7.54x");
    for (m, s) in &per_model {
        let a = s.iter().sum::<f64>() / s.len() as f64;
        println!("  {m}: avg {a:.2}x (paper: 0.5b 5.50x / 7b 2.03x)");
    }

    // Shape assertions (who wins, qualitative ordering):
    assert!(speedups.iter().all(|&s| s > 1.0), "Skrull must beat baseline everywhere");
    let s05: f64 = per_model["qwen2.5-0.5b"].iter().sum::<f64>() / 3.0;
    let s7: f64 = per_model["qwen2.5-7b"].iter().sum::<f64>() / 3.0;
    assert!(s05 > s7, "0.5B (larger BucketSize) must gain more than 7B");
    println!("shape checks OK");
}
