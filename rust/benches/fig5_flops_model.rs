//! Figure 5 (Appendix A.2): FLOPs vs sequence length for Qwen2.5-0.5B and
//! 7B — the hybrid linear/quadratic dependence, the crossover where
//! attention dominates, and the 32K-vs-4K workload ratio the paper quotes.

use skrull::bench::TableBuilder;
use skrull::model::ModelSpec;
use skrull::perfmodel::FlopsModel;

fn main() {
    let m05 = FlopsModel::new(&ModelSpec::qwen2_5_0_5b());
    let m7 = FlopsModel::new(&ModelSpec::qwen2_5_7b());

    let mut table = TableBuilder::new("Figure 5: FLOPs vs sequence length (whole model, Eq. 13)")
        .header(&[
            "SeqLen", "0.5B TFLOPs", "0.5B attn%", "7B TFLOPs", "7B attn%",
        ]);
    for s in [256u32, 512, 1024, 2048, 4096, 8192, 16_384, 32_768, 65_536, 131_072] {
        table.row(&[
            skrull::util::fmt_tokens(s as u64),
            format!("{:.2}", m05.seq(s) / 1e12),
            format!("{:.1}%", 100.0 * m05.attn(s) / m05.seq(s)),
            format!("{:.2}", m7.seq(s) / 1e12),
            format!("{:.1}%", 100.0 * m7.attn(s) / m7.seq(s)),
        ]);
    }
    table.print();

    let x05 = m05.quadratic_crossover();
    let x7 = m7.quadratic_crossover();
    println!("quadratic-term crossover: 0.5B at {:.0} tokens, 7B at {:.0} tokens", x05, x7);

    // Paper claims (App. A.2), asserted:
    // "the quadratic term begins to dominate only when S exceeds ~4K" (0.5B)
    assert!((3_000.0..6_000.0).contains(&x05), "0.5B crossover {x05}");
    // "when S=32K the total workload is 30x greater than when S=4K, while
    // memory increases only 4-fold" (memory is 8x tokens but 4x was vs a
    // different base in the paper's accounting; we check FLOPs: ~30x)
    let ratio = m05.seq(32 * 1024) / m05.seq(4 * 1024);
    println!("0.5B FLOPs(32K)/FLOPs(4K) = {ratio:.1} (paper: ~30x)");
    assert!((20.0..40.0).contains(&ratio));
    // "Qwen2.5-7B, which has a larger hidden dimension h, exhibits a more
    // rapid increase in FLOPs" — absolute FLOPs grow faster at every
    // length, and the crossover moves to longer sequences.
    for s in [1024u32, 8192, 65_536] {
        assert!(
            m7.seq(s) - m7.seq(s / 2) > m05.seq(s) - m05.seq(s / 2),
            "7B must add more FLOPs per added token at S={s}"
        );
    }
    assert!(x7 > x05, "larger h defers the quadratic crossover");
    let growth05 = m05.seq(131_072) / m05.seq(1024);
    let growth7 = m7.seq(131_072) / m7.seq(1024);
    println!("FLOPs growth 1K→128K: 0.5B {growth05:.0}x, 7B {growth7:.0}x");
    println!("shape checks OK");
}
