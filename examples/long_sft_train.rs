//! End-to-end Long-SFT validation on a real workload (DESIGN.md §6, E2E):
//! trains the tiny Qwen-style transformer through the full three-layer
//! stack — rust scheduler → packed buckets → AOT HLO artifacts (JAX +
//! Pallas flash-attention) → PJRT CPU execution → host-side AdamW — under
//! both the DeepSpeed-like baseline and Skrull scheduling, and reports:
//!
//!   * the loss curves (must both learn: the corpus is a noisy Markov
//!     process with a known entropy floor)
//!   * executed-token and micro-batch counts (Skrull's packing win)
//!   * measured wall-clock per policy on this machine
//!
//! Run `make artifacts` first.  ~200 steps ≈ a few minutes on CPU.
//!
//!   cargo run --release --offline --example long_sft_train -- [steps] [bucket]
//!
//! Substrate note: both policies execute the same fixed bucket size
//! (default 256 tokens) so the comparison isolates the paper's packing /
//! launch-count mechanism.  A dense interpret-mode attention kernel pays
//! t² for the whole bucket regardless of segment masks, so packing into
//! *larger* buckets than the baseline's would conflate the scheduler's
//! win with the kernel's (lack of) block skipping — on a real TPU/GPU,
//! FlashAttention's varlen block-skip removes that term (DESIGN.md §4).

use skrull::config::Policy;
use skrull::coordinator::corpus::CorpusConfig;
use skrull::coordinator::{Trainer, TrainerOptions};
use skrull::data::LengthDistribution;
use skrull::rng::Rng;
use skrull::util::fmt_secs;

fn main() -> skrull::util::error::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let bucket: u32 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let artifacts = std::env::var("SKRULL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // tiny Long-SFT corpus: long-tail lengths with median ≪ bucket, the
    // paper's regime (Wikipedia median ≈ 290 tokens vs C = 26K — buckets
    // hold dozens of sequences); learnable Markov structure
    let corpus_cfg = CorpusConfig::tiny(512);
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    let dist = LengthDistribution::LognormalMixture {
        name: "tiny-longtail",
        components: vec![(0.95, 3.7, 0.7), (0.05, 5.2, 0.3)],
        max_len: bucket,
    };
    let lens: Vec<u32> = (0..512).map(|_| dist.sample(&mut rng).max(2)).collect();
    let corpus = corpus_cfg.corpus(0x5EED, &lens);
    let total_tokens: usize = corpus.iter().map(|s| s.tokens.len()).sum();
    println!(
        "corpus: {} sequences, {} tokens (mean {:.0}), entropy floor {:.3} nats/token",
        corpus.len(),
        total_tokens,
        total_tokens as f64 / corpus.len() as f64,
        corpus_cfg.entropy_floor()
    );

    let mut results = Vec::new();
    for policy in [Policy::Baseline, Policy::Skrull] {
        // 4 emulated CP workers: the global batch (~16×59 tokens) fits in
        // one micro-batch with per-rank slack, so DACP packs shorts locally
        // instead of memory-pressure sharding — the regime where Long-SFT
        // spends most of its time (87%+ of sequences are short, Table 1).
        let opts = TrainerOptions {
            workers: 4,
            bucket_capacity: bucket,
            policy,
            lr: 3e-3,
            seed: 42,
            batch_size: 16,
            ..Default::default()
        };
        println!("\n=== policy {:?}: {steps} steps ===", policy);
        let mut trainer = Trainer::new(&artifacts, opts)?;
        let report = trainer.train(&corpus, steps)?;
        println!(
            "wall {} (compile {}), {} buckets executed, {} tokens executed ({:.1}% padding)",
            fmt_secs(report.wall_seconds),
            fmt_secs(report.compile_seconds),
            report.buckets_executed,
            report.executed_tokens,
            100.0 * report.padding_fraction()
        );
        println!(
            "loss {:.4} -> {:.4}, scheduler overhead/step {}",
            report.metrics.first_loss().unwrap_or(f32::NAN),
            report.metrics.final_loss(10).unwrap_or(f32::NAN),
            fmt_secs(report.metrics.sched_seconds / steps as f64)
        );
        println!("loss curve (every {} steps):", (steps / 10).max(1));
        print!("{}", report.metrics.render_curve((steps / 10).max(1)));
        results.push((policy, report));
    }

    let (_, base) = &results[0];
    let (_, skr) = &results[1];
    let exec_speedup =
        (base.wall_seconds - base.compile_seconds) / (skr.wall_seconds - skr.compile_seconds);
    println!("\n=== summary ===");
    println!(
        "executed tokens: baseline {} vs skrull {} ({:.2}x fewer)",
        base.executed_tokens,
        skr.executed_tokens,
        base.executed_tokens as f64 / skr.executed_tokens as f64
    );
    println!(
        "micro-batches:   baseline {} vs skrull {} ({:.2}x fewer)",
        base.buckets_executed,
        skr.buckets_executed,
        base.buckets_executed as f64 / skr.buckets_executed as f64
    );
    println!("measured wall-clock speedup (excl. compile): {exec_speedup:.2}x");
    let floor = corpus_cfg.entropy_floor() as f32;
    let b_final = base.metrics.final_loss(10).unwrap();
    let s_final = skr.metrics.final_loss(10).unwrap();
    println!(
        "final loss: baseline {b_final:.4} vs skrull {s_final:.4} (floor {floor:.4}) — both must learn"
    );
    assert!(
        b_final < base.metrics.first_loss().unwrap() * 0.7,
        "baseline failed to learn"
    );
    assert!(
        s_final < skr.metrics.first_loss().unwrap() * 0.7,
        "skrull failed to learn"
    );
    assert!(
        skr.executed_tokens < base.executed_tokens,
        "skrull must execute fewer (padded) tokens"
    );
    println!("e2e validation OK: identical learning, fewer executed tokens under Skrull");
    Ok(())
}
