//! Quickstart: the Skrull public API in ~60 lines.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Synthesizes a Long-SFT dataset, schedules one global batch with GDS +
//! DACP, and compares the simulated iteration time against the DeepSpeed
//! baseline — the paper's headline experiment in miniature.

use skrull::cluster::simulate_iteration;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;
use skrull::util::{fmt_secs, fmt_tokens};

fn main() -> skrull::util::error::Result<()> {
    // 1. the paper's evaluation setting: Qwen2.5-0.5B, <DP=4, CP=8, B=64>,
    //    BucketSize C = 26K tokens
    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), "wikipedia");
    println!(
        "model={} <DP={}, CP={}, BatchSize={}> C={}",
        cfg.model.name,
        cfg.cluster.dp,
        cfg.cluster.cp,
        cfg.cluster.batch_size,
        fmt_tokens(cfg.bucket_size as u64)
    );

    // 2. a synthetic dataset matching Wikipedia's long-tail distribution
    let dist = LengthDistribution::wikipedia();
    let dataset = Dataset::synthesize(&dist, 50_000, 7);
    println!(
        "dataset: {} sequences, {} tokens, longest {}",
        dataset.len(),
        fmt_tokens(dataset.total_tokens()),
        fmt_tokens(dataset.max_len() as u64)
    );

    // 3. schedule one global batch under each policy and simulate it
    let cost = CostModel::paper_default(&cfg.model);
    let mut baseline_time = None;
    for policy in [Policy::Baseline, Policy::DacpOnly, Policy::Skrull] {
        let mut pcfg = cfg.clone();
        pcfg.policy = policy;
        let mut loader = ScheduledLoader::new(&dataset, &pcfg);
        let (_batch, sched) = loader.next_iteration()?;
        let sim = simulate_iteration(&sched, &cost, cfg.cluster.cp);
        let speedup = baseline_time
            .map(|b: f64| format!("{:.2}x", b / sim.total_time))
            .unwrap_or_else(|| "1.00x".into());
        baseline_time.get_or_insert(sim.total_time);
        println!(
            "  {:<10} {} micro-batches, iteration {}, utilization {:>5.1}%, speedup {}",
            policy.name(),
            sched.num_micro_batches(),
            fmt_secs(sim.total_time),
            100.0 * sim.compute_utilization,
            speedup
        );
    }
    println!("\n(see examples/cluster_sim.rs for the full Figure-3 sweep,");
    println!(" and examples/long_sft_train.rs for real PJRT training)");
    Ok(())
}
