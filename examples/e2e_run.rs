//! End-to-end run-engine walkthrough: one workload, every policy, with the
//! pipelined DataLoader's overhead hiding made visible.
//!
//!   cargo run --release --offline --example e2e_run -- [dataset] [iterations]
//!
//! Prints per-policy end-to-end wall-clock + speedup, then contrasts the
//! synchronous and pipelined loader modes on the Skrull policy (identical
//! schedules, different exposed scheduling time), and writes a multi-
//! iteration chrome trace with the dataloader lane.

use skrull::cluster::run::{build_run, price_run, simulate_run, RunConfig};
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;
use skrull::util::fmt_secs;

fn main() -> skrull::util::error::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "chatqa2".into());
    let iterations: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()
        .map_err(|_| skrull::anyhow!("iterations must be a number"))?
        .unwrap_or(8);

    let cfg = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), &dataset);
    let dist = LengthDistribution::by_name(&dataset)
        .ok_or_else(|| skrull::anyhow!("unknown dataset {dataset}"))?;
    let ds = Dataset::synthesize(&dist, 20_000, cfg.seed ^ 0xD5)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg.model);

    println!(
        "{} iterations of {} on <DP={},CP={}> (simulated cluster, measured scheduler)\n",
        iterations, ds.name, cfg.cluster.dp, cfg.cluster.cp
    );

    // every policy, pipelined loader
    let run = RunConfig::new(iterations, true);
    let mut base = None;
    for policy in skrull::bench::e2e::ALL_POLICIES {
        let mut pcfg = cfg.clone();
        pcfg.policy = policy;
        let r = simulate_run(&ds, &pcfg, &cost, &run)?;
        let wall = r.wall_seconds();
        let b = *base.get_or_insert(wall);
        println!(
            "  {:<15} total {}  speedup {:.2}x  util {:.1}%  padding {:.1}%  peak mem {:.1}%  oom {}  exposed sched {}",
            policy.name(),
            fmt_secs(wall),
            b / wall,
            100.0 * r.utilization(),
            100.0 * r.padding_fraction(),
            100.0 * r.peak_mem_fraction(),
            r.oom_count(),
            fmt_secs(r.exposed_sched_seconds),
        );
    }

    // loader-mode contrast on Skrull: scheduling hides behind execution
    println!("\nloader modes (Skrull):");
    for pipelined in [false, true] {
        let r = simulate_run(&ds, &cfg, &cost, &RunConfig::new(iterations, pipelined))?;
        println!(
            "  {:<12} wall {}  sched total {}  exposed {}  overhead {:.4}%",
            if pipelined { "pipelined" } else { "synchronous" },
            fmt_secs(r.wall_seconds()),
            fmt_secs(r.sched_seconds),
            fmt_secs(r.exposed_sched_seconds),
            100.0 * r.sched_overhead_fraction(),
        );
    }

    // build once, price many: one scheduling pass produces the report,
    // a what-if repricing under a degraded interconnect, and the chrome
    // trace — no loader replays
    let n_trace = iterations.min(3);
    let built = build_run(&ds, &cfg, &RunConfig::new(n_trace, true))?;
    let report = price_run(&built, &cost, &built.topology);
    let degraded = price_run(&built, &cost.with_cross_node_cp(), &built.topology);
    println!(
        "\nbuild-once/price-many ({} scheduling passes for {} pricings):",
        built.sched_invocations,
        2
    );
    println!(
        "  NVLink CP rings: exec {}   all-IB what-if: exec {}  ({:.2}x slower)",
        fmt_secs(report.exec_seconds),
        fmt_secs(degraded.exec_seconds),
        degraded.exec_seconds / report.exec_seconds,
    );
    let trace = skrull::cluster::trace::run_trace_built(&built, &report, &cost);
    let path = std::env::temp_dir().join("skrull_run_trace.json");
    std::fs::write(&path, trace)?;
    println!("{n_trace}-iteration chrome trace written to {}", path.display());
    Ok(())
}
