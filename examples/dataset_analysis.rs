//! Dataset analysis (Fig. 1a / Table 1 companion): distribution summary,
//! percentile table, and — the scheduler's-eye view — how many BucketSize-C
//! buckets a sampled global batch actually needs under each policy, i.e.
//! the packing-density story behind the speedups.
//!
//!   cargo run --release --offline --example dataset_analysis -- [dataset]

use skrull::bench::TableBuilder;
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::util::fmt_tokens;
use skrull::util::stats::{fraction_below, Summary};

fn main() -> skrull::util::error::Result<()> {
    let which = std::env::args().nth(1);
    let names: Vec<&str> = match which.as_deref() {
        Some(n) => vec![match n {
            "wikipedia" | "wiki" => "wikipedia",
            "lmsys" => "lmsys",
            "chatqa2" => "chatqa2",
            other => skrull::bail!("unknown dataset {other}"),
        }],
        None => vec!["wikipedia", "lmsys", "chatqa2"],
    };

    let mut table = TableBuilder::new("Table 1 view: synthesized Long-SFT datasets (n=200k)")
        .header(&["Dataset", "<1K", "<4K", "<8K", "<32K", "mean", "p50", "p99", "longest"]);
    for name in &names {
        let dist = LengthDistribution::by_name(name).unwrap();
        let ds = Dataset::synthesize(&dist, 200_000, 42);
        let mut s = Summary::new();
        for &l in &ds.lengths {
            s.push(l as f64);
        }
        table.row(&[
            name.to_string(),
            format!("{:.2}%", 100.0 * fraction_below(&ds.lengths, 1024)),
            format!("{:.2}%", 100.0 * fraction_below(&ds.lengths, 4096)),
            format!("{:.2}%", 100.0 * fraction_below(&ds.lengths, 8192)),
            format!("{:.2}%", 100.0 * fraction_below(&ds.lengths, 32 * 1024)),
            format!("{:.0}", s.mean()),
            format!("{:.0}", s.quantile(0.5)),
            format!("{:.0}", s.quantile(0.99)),
            fmt_tokens(s.max() as u64),
        ]);
    }
    table.print();

    // Scheduler's-eye view: micro-batch counts + sharded sequences per
    // policy for one sampled global batch of each dataset.
    let mut t2 = TableBuilder::new(
        "Scheduling view (Qwen2.5-0.5B, <DP=4,CP=8,B=64>, C=26K): one global batch",
    )
    .header(&["Dataset", "policy", "micro-batches", "sharded seqs", "tokens/bucket"]);
    for name in &names {
        let cfg0 = ExperimentConfig::paper_default(ModelSpec::qwen2_5_0_5b(), name);
        let dist = LengthDistribution::by_name(name).unwrap();
        let ds = Dataset::synthesize(&dist, 100_000, 42)
            .truncated(cfg0.bucket_size * cfg0.cluster.cp as u32);
        for policy in [Policy::Baseline, Policy::Skrull] {
            let mut cfg = cfg0.clone();
            cfg.policy = policy;
            let mut loader = ScheduledLoader::new(&ds, &cfg);
            let (batch, sched) = loader.next_iteration()?;
            let mbs = sched.num_micro_batches();
            let sharded: usize = sched
                .ranks
                .iter()
                .flat_map(|r| &r.micro_batches)
                .map(|mb| mb.plan.num_distributed())
                .sum();
            let total: u64 = batch.iter().map(|s| s.len as u64).sum();
            t2.row(&[
                name.to_string(),
                policy.name().to_string(),
                mbs.to_string(),
                format!("{sharded}/{}", batch.len()),
                fmt_tokens(total / mbs.max(1) as u64),
            ]);
        }
    }
    t2.print();
    println!("(fewer micro-batches at equal tokens = denser packing = higher GPU utilization)");
    Ok(())
}
