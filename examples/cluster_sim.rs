//! Cluster simulation walkthrough: one Figure-3 cell in detail.
//!
//! Simulates iterations of Long-SFT on the paper's 32-GPU testbed and
//! prints, for one iteration, a per-DP-rank timeline of micro-batches with
//! their Eq. 2 decomposition (local compute vs exposed comm vs distributed
//! compute) — the Fig. 2(d) picture, numerically.
//!
//!   cargo run --release --offline --example cluster_sim -- [dataset] [model]

use skrull::cluster::{simulate_iteration, Topology};
use skrull::config::{ExperimentConfig, Policy};
use skrull::data::loader::ScheduledLoader;
use skrull::data::{Dataset, LengthDistribution};
use skrull::model::ModelSpec;
use skrull::perfmodel::CostModel;
use skrull::util::{fmt_secs, fmt_tokens};

fn main() -> skrull::util::error::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "chatqa2".into());
    let model_name = std::env::args().nth(2).unwrap_or_else(|| "qwen2.5-0.5b".into());
    let model = ModelSpec::by_name(&model_name)
        .ok_or_else(|| skrull::anyhow!("unknown model {model_name}"))?;
    let cfg = ExperimentConfig::paper_default(model, &dataset);

    let topo = Topology::paper_testbed(cfg.cluster.dp, cfg.cluster.cp)?;
    println!(
        "testbed: {} nodes × {} GPUs, DP={} × CP={} ({} GPUs), CP groups {} node boundaries",
        topo.nodes,
        topo.gpus_per_node,
        topo.dp,
        topo.cp,
        topo.total_gpus(),
        if topo.cp_group_crosses_nodes(0) { "CROSS" } else { "stay within" },
    );

    let dist = LengthDistribution::by_name(&dataset)
        .ok_or_else(|| skrull::anyhow!("unknown dataset {dataset}"))?;
    let ds = Dataset::synthesize(&dist, 100_000, cfg.seed ^ 0xD5)
        .truncated(cfg.bucket_size * cfg.cluster.cp as u32);
    let cost = CostModel::paper_default(&cfg.model);

    // one iteration, in detail, under Skrull
    let mut skrull_cfg = cfg.clone();
    skrull_cfg.policy = Policy::Skrull;
    let mut loader = ScheduledLoader::new(&ds, &skrull_cfg);
    let (batch, sched) = loader.next_iteration()?;
    let sim = simulate_iteration(&sched, &cost, cfg.cluster.cp);

    println!(
        "\none Skrull-scheduled iteration ({} seqs, {} tokens):",
        batch.len(),
        fmt_tokens(batch.iter().map(|s| s.len as u64).sum())
    );
    for (d, (rank, sims)) in sched.ranks.iter().zip(&sim.micro_batches).enumerate() {
        println!("  dp{d} (span {}):", fmt_secs(sim.rank_spans[d]));
        for (mb, s) in rank.micro_batches.iter().zip(sims) {
            let max_local = s.busy.iter().cloned().fold(0.0, f64::max);
            let exp_comm = s.exposed_comm.iter().cloned().fold(0.0, f64::max);
            println!(
                "    mb: {:>2} seqs ({} tokens) = {} local + {} sharded | tdacp {} (worst rank: busy {}, exposed comm {})",
                mb.seqs.len(),
                fmt_tokens(mb.total_tokens()),
                s.num_local,
                s.num_distributed,
                fmt_secs(s.tdacp),
                fmt_secs(max_local),
                fmt_secs(exp_comm),
            );
        }
    }
    println!(
        "iteration {} = slowest dp span {} + grad sync {}; utilization {:.1}%",
        fmt_secs(sim.total_time),
        fmt_secs(sim.rank_spans.iter().cloned().fold(0.0, f64::max)),
        fmt_secs(sim.grad_sync),
        100.0 * sim.compute_utilization
    );

    // export the timeline as a chrome://tracing / Perfetto trace
    let trace_path = std::env::temp_dir().join("skrull_iteration_trace.json");
    skrull::cluster::trace::write_iteration_trace(
        trace_path.to_str().unwrap(),
        &sched,
        &cost,
        cfg.cluster.cp,
    )?;
    println!("\nchrome trace written to {}", trace_path.display());

    // then the policy comparison over several iterations
    println!("\npolicy comparison (15 iterations):");
    let mut base = None;
    for policy in [Policy::Baseline, Policy::DacpOnly, Policy::Skrull, Policy::SortedBatching] {
        let mut pcfg = cfg.clone();
        pcfg.policy = policy;
        let mut loader = ScheduledLoader::new(&ds, &pcfg);
        let mut total = 0.0;
        let mut util = 0.0;
        for _ in 0..15 {
            let (_, sched) = loader.next_iteration()?;
            let s = simulate_iteration(&sched, &cost, cfg.cluster.cp);
            total += s.total_time;
            util += s.compute_utilization;
        }
        let mean = total / 15.0;
        let b = *base.get_or_insert(mean);
        println!(
            "  {:<10} mean iter {}  speedup {:.2}x  utilization {:.1}%",
            policy.name(),
            fmt_secs(mean),
            b / mean,
            100.0 * util / 15.0
        );
    }
    Ok(())
}
